"""Cell builder: one (architecture × input-shape) dry-run/smoke unit.

A cell packages the step function (train_step / prefill / decode / serve /
retrieval), abstract arguments (ShapeDtypeStructs — no allocation), and the
in/out shardings for a mesh.  The dry-run lowers cells on the production
meshes; smoke tests execute reduced cells on real (tiny) arrays.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeSpec, get_arch
from repro.configs.graphcast import gnn_cfg_for_shape, gnn_input_specs
from repro.configs.lm_common import lm_input_specs
from repro.configs.recsys_common import ctr_input_specs, seq_input_specs
from repro.distributed import optimizer as opt_lib
from repro.distributed.sharding import (
    axis_size,
    batch_shardings,
    dp_axes,
    lm_cache_shardings,
    opt_state_shardings,
    param_shardings,
)
from repro.models import gnn, recsys, transformer


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    family: str
    kind: str
    fn: Callable
    abstract_args: Tuple[Any, ...]
    in_shardings: Optional[Tuple[Any, ...]]
    out_shardings: Optional[Any]
    make_real_args: Callable[[jax.Array], Tuple[Any, ...]]  # smoke tests
    cfg: Any


def _replicate_like(tree, mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, P(*([None] * len(getattr(s, "shape", ()))))), tree
    )


def _production_dtype(cfg, reduced: bool):
    """Full-scale cells run bf16 (the roofline target dtype); reduced smoke
    cells stay f32 for test tolerance."""
    if reduced or not hasattr(cfg, "dtype"):
        return cfg
    return dataclasses.replace(cfg, dtype=jnp.bfloat16)


# ---------------------------------------------------------------------- LM
def _lm_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, reduced: bool, variant: str = "base"
) -> Cell:
    cfg = _production_dtype(spec.reduced_cfg if reduced else spec.model_cfg, reduced)
    if cfg.is_moe and mesh is not None:
        g = axis_size(mesh, dp_axes(mesh))
        cfg = dataclasses.replace(cfg, moe_groups=g)
    if variant == "opt" and mesh is not None:
        # §Perf/H1: vocab-sharded logits + activation sharding constraints
        # (without act_dp, XLA propagates FSDP weight shardings onto the
        # residual stream and batch becomes replicated — see EXPERIMENTS.md)
        cfg = dataclasses.replace(
            cfg,
            logits_pspec=(dp_axes(mesh), None, "model"),
            act_dp=dp_axes(mesh),
            act_tp="model",
        )
    specs = lm_input_specs(cfg, shape, reduced=reduced)
    params_sds = jax.eval_shape(lambda: transformer.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("lm", params_sds, mesh) if mesh else None

    if shape.kind == "train":
        optimizer = opt_lib.for_arch("lm", spec.arch_id)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        o_sh = opt_state_shardings(opt_sds, p_sh, mesh) if mesh else None
        # §Perf/H1-iter3: microbatched gradient accumulation divides the
        # stacked-residual live memory by accum_steps at zero collective cost
        accum = 4 if (variant == "opt" and not reduced) else 1

        def train_step(params, opt_state, batch):
            if accum == 1:
                loss, grads = jax.value_and_grad(transformer.loss_fn)(
                    params, batch, cfg
                )
            else:
                micro = jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum, *x.shape[1:]),
                    batch,
                )

                def body(acc, mb):
                    l, g = jax.value_and_grad(transformer.loss_fn)(params, mb, cfg)
                    return jax.tree.map(jnp.add, acc, g), l

                zeros = jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                )
                grads, losses = jax.lax.scan(
                    body, zeros, micro,
                    unroll=accum if getattr(cfg, "scan_unroll", False) else 1,
                )
                grads = jax.tree.map(lambda g: g / accum, grads)
                loss = losses.mean()
            params, opt_state = optimizer.update(grads, opt_state, params)
            return params, opt_state, loss

        b_sh = batch_shardings(specs, mesh, "lm") if mesh else None
        return Cell(
            spec.arch_id, shape.name, "lm", "train",
            train_step,
            (params_sds, opt_sds, specs),
            (p_sh, o_sh, b_sh) if mesh else None,
            (p_sh, o_sh, NamedSharding(mesh, P())) if mesh else None,
            lambda key: _lm_real_train(key, cfg, specs, optimizer),
            cfg,
        )

    if shape.kind == "prefill":
        def prefill(params, tokens):
            return transformer.forward(params, tokens, cfg)

        b_sh = batch_shardings(specs, mesh, "lm") if mesh else None
        return Cell(
            spec.arch_id, shape.name, "lm", "prefill",
            prefill,
            (params_sds, specs["tokens"]),
            (p_sh, b_sh["tokens"]) if mesh else None,
            None,
            lambda key: _lm_real_prefill(key, cfg, specs),
            cfg,
        )

    # decode
    cache_sds = specs["cache"]
    c_sh = lm_cache_shardings(cache_sds, mesh) if mesh else None

    def serve_step(params, cache, tokens, position):
        return transformer.decode_step(params, cache, tokens, position, cfg)

    tok_sh = (
        batch_shardings({"tokens": specs["tokens"]}, mesh, "lm")["tokens"]
        if mesh
        else None
    )
    return Cell(
        spec.arch_id, shape.name, "lm", "decode",
        serve_step,
        (params_sds, cache_sds, specs["tokens"], specs["position"]),
        (p_sh, c_sh, tok_sh, NamedSharding(mesh, P())) if mesh else None,
        (None, c_sh) if mesh else None,
        lambda key: _lm_real_decode(key, cfg, specs),
        cfg,
    )


def _lm_real_train(key, cfg, specs, optimizer):
    params = transformer.init_params(key, cfg)
    opt_state = optimizer.init(params)
    B, S = specs["tokens"].shape
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return params, opt_state, {"tokens": toks, "labels": toks}


def _lm_real_prefill(key, cfg, specs):
    params = transformer.init_params(key, cfg)
    B, S = specs["tokens"].shape
    return params, jax.random.randint(key, (B, S), 0, cfg.vocab)


def _lm_real_decode(key, cfg, specs):
    params = transformer.init_params(key, cfg)
    cache = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), specs["cache"])
    B = specs["tokens"].shape[0]
    return params, cache, jax.random.randint(key, (B, 1), 0, cfg.vocab), jnp.int32(3)


# --------------------------------------------------------------------- GNN
def _gnn_cell(
    spec: ArchSpec, shape: ShapeSpec, mesh, reduced: bool, variant: str = "base"
) -> Cell:
    base_cfg = spec.reduced_cfg if reduced else spec.model_cfg
    cfg = gnn_cfg_for_shape(base_cfg, shape) if not reduced else dataclasses.replace(
        gnn_cfg_for_shape(base_cfg, shape), n_layers=base_cfg.n_layers,
        d_hidden=base_cfg.d_hidden, remat=False
    )
    cfg = _production_dtype(cfg, reduced)
    if variant == "opt" and mesh is not None:
        # §Perf/H2: GNN params are replicated, so the 'model' axis is idle —
        # row-shard node/edge activations over ALL mesh axes (256-way, not 16)
        cfg = dataclasses.replace(cfg, act_axes=tuple(mesh.axis_names))
    specs = gnn_input_specs(shape, reduced=reduced)
    params_sds = jax.eval_shape(lambda: gnn.init_params(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("gnn", params_sds, mesh) if mesh else None
    optimizer = opt_lib.for_arch("gnn", spec.arch_id)
    opt_sds = jax.eval_shape(optimizer.init, params_sds)
    o_sh = opt_state_shardings(opt_sds, p_sh, mesh) if mesh else None
    loss = gnn.loss_fn_batched if shape.name == "molecule" else gnn.loss_fn

    def train_step(params, opt_state, batch):
        l, grads = jax.value_and_grad(loss)(params, batch, cfg)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, l

    b_sh = batch_shardings(specs, mesh, "gnn") if mesh else None
    if variant == "opt" and mesh is not None:
        # inputs row-sharded over ALL axes to match act_axes
        row = tuple(mesh.axis_names)

        def _row_shard(sds):
            if not hasattr(sds, "shape") or len(sds.shape) == 0:
                return NamedSharding(mesh, P())
            if sds.shape[0] % axis_size(mesh, row) == 0:
                return NamedSharding(
                    mesh, P(row, *([None] * (len(sds.shape) - 1)))
                )
            return NamedSharding(mesh, P(*([None] * len(sds.shape))))

        b_sh = jax.tree.map(_row_shard, specs)
    return Cell(
        spec.arch_id, shape.name, "gnn", "train",
        train_step,
        (params_sds, opt_sds, specs),
        (p_sh, o_sh, b_sh) if mesh else None,
        (p_sh, o_sh, NamedSharding(mesh, P())) if mesh else None,
        lambda key: _gnn_real(key, cfg, specs, optimizer, shape),
        cfg,
    )


def _gnn_real(key, cfg, specs, optimizer, shape):
    params = gnn.init_params(key, cfg)
    opt_state = optimizer.init(params)
    rng = np.random.default_rng(0)
    batch = {}
    for k, s in specs.items():
        if k == "edges":
            n_nodes = specs["nodes"].shape[-2]
            batch[k] = jnp.asarray(
                rng.integers(0, n_nodes, s.shape), jnp.int32
            )
        elif s.dtype == jnp.int32:
            batch[k] = jnp.zeros(s.shape, jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.normal(size=s.shape) * 0.1, s.dtype)
    if "edge_mask" in batch:
        batch["edge_mask"] = jnp.ones(specs["edge_mask"].shape, jnp.float32)
    if "node_mask" in batch:
        batch["node_mask"] = jnp.ones(specs["node_mask"].shape, jnp.float32)
    return params, opt_state, batch


# ------------------------------------------------------------------ recsys
def _recsys_cell(spec: ArchSpec, shape: ShapeSpec, mesh, reduced: bool) -> Cell:
    cfg = _production_dtype(spec.reduced_cfg if reduced else spec.model_cfg, reduced)
    arch = spec.arch_id
    if arch == "xdeepfm":
        specs = ctr_input_specs(shape, cfg.n_sparse, 0, reduced=reduced)
        init_fn = recsys.xdeepfm_init
        loss_fn = recsys.xdeepfm_loss
        fwd = lambda p, b: recsys.xdeepfm_forward(p, b["sparse_ids"], cfg)
    elif arch == "dcn-v2":
        specs = ctr_input_specs(shape, cfg.n_sparse, cfg.n_dense, reduced=reduced)
        init_fn = recsys.dcnv2_init
        loss_fn = recsys.dcnv2_loss
        fwd = lambda p, b: recsys.dcnv2_forward(p, b["dense"], b["sparse_ids"], cfg)
    elif arch == "sasrec":
        specs = seq_input_specs(shape, cfg.seq_len, reduced=reduced)
        init_fn = recsys.sasrec_init
        loss_fn = recsys.sasrec_loss
        fwd = lambda p, b: recsys.sasrec_encode(p, b["history"], cfg)
    elif arch == "mind":
        specs = seq_input_specs(shape, cfg.seq_len, reduced=reduced)
        init_fn = recsys.mind_init
        loss_fn = recsys.mind_loss
        fwd = lambda p, b: recsys.mind_interests(p, b["history"], cfg)
    else:
        raise ValueError(arch)

    params_sds = jax.eval_shape(lambda: init_fn(jax.random.PRNGKey(0), cfg))
    p_sh = param_shardings("recsys", params_sds, mesh) if mesh else None
    b_sh = batch_shardings(specs, mesh, "recsys") if mesh else None

    if shape.kind == "train":
        optimizer = opt_lib.for_arch("recsys", arch)
        opt_sds = jax.eval_shape(optimizer.init, params_sds)
        o_sh = opt_state_shardings(opt_sds, p_sh, mesh) if mesh else None

        def train_step(params, opt_state, batch):
            l, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
            params, opt_state = optimizer.update(grads, opt_state, params)
            return params, opt_state, l

        return Cell(
            arch, shape.name, "recsys", "train",
            train_step,
            (params_sds, opt_sds, specs),
            (p_sh, o_sh, b_sh) if mesh else None,
            (p_sh, o_sh, NamedSharding(mesh, P())) if mesh else None,
            lambda key: _recsys_real(key, cfg, specs, init_fn, optimizer),
            cfg,
        )

    if shape.kind == "serve":
        def serve(params, batch):
            return fwd(params, batch)

        return Cell(
            arch, shape.name, "recsys", "serve",
            serve,
            (params_sds, specs),
            (p_sh, b_sh) if mesh else None,
            None,
            lambda key: _recsys_real(key, cfg, specs, init_fn, None),
            cfg,
        )

    # retrieval: 1 query x 1M candidates — single batched matmul / bulk pass
    if arch in ("sasrec", "mind"):
        score = recsys.sasrec_score_candidates if arch == "sasrec" else recsys.mind_score_candidates

        def retrieval(params, batch):
            return score(params, batch["history"], batch["candidates"], cfg)
    else:
        def retrieval(params, batch):
            base = batch["base_ids"]  # (1, m)
            cands = batch["candidates"]  # (N,)
            n = cands.shape[0]
            ids = jnp.broadcast_to(base, (n, base.shape[1]))
            ids = ids.at[:, 0].set(cands)  # candidate item in field 0
            if arch == "dcn-v2":
                dense = jnp.broadcast_to(batch["dense"], (n, batch["dense"].shape[1]))
                return recsys.dcnv2_forward(params, dense, ids, cfg)
            return recsys.xdeepfm_forward(params, ids, cfg)

    return Cell(
        arch, shape.name, "recsys", "retrieval",
        retrieval,
        (params_sds, specs),
        (p_sh, b_sh) if mesh else None,
        None,
        lambda key: _recsys_real(key, cfg, specs, init_fn, None),
        cfg,
    )


def _recsys_real(key, cfg, specs, init_fn, optimizer):
    params = init_fn(key, cfg)
    rng = np.random.default_rng(0)
    vocab = getattr(cfg, "vocab_per_field", None) or getattr(cfg, "n_items")
    batch = {}
    for k, s in specs.items():
        if s.dtype == jnp.int32:
            batch[k] = jnp.asarray(rng.integers(0, vocab, s.shape), jnp.int32)
        else:
            batch[k] = jnp.asarray(rng.normal(size=s.shape), jnp.float32)
    if "labels" in batch:
        batch["labels"] = jnp.asarray(rng.integers(0, 2, specs["labels"].shape), jnp.float32)
    if optimizer is not None:
        return params, optimizer.init(params), batch
    return params, batch


# ------------------------------------------------------------------- public
def build_cell(
    arch_id: str,
    shape_name: str,
    mesh: Optional[Mesh] = None,
    *,
    reduced: bool = False,
    variant: str = "base",
    unroll: bool = False,
) -> Cell:
    spec = get_arch(arch_id)
    shape = spec.shape(shape_name)
    if shape.skip and not reduced:
        raise ValueError(f"cell {arch_id}×{shape_name} skipped: {shape.skip}")
    if spec.family == "lm":
        cell = _lm_cell(spec, shape, mesh, reduced, variant)
    elif spec.family == "gnn":
        cell = _gnn_cell(spec, shape, mesh, reduced, variant)
    else:
        cell = _recsys_cell(spec, shape, mesh, reduced)
    if unroll and hasattr(cell.cfg, "scan_unroll") and spec.family in ("lm", "gnn"):
        # flop-accounting mode: rebuild the cell with the layer scan unrolled
        # (cfg is captured in the step closure, so rebuild from a patched spec)
        spec2 = dataclasses.replace(
            spec,
            model_cfg=dataclasses.replace(spec.model_cfg, scan_unroll=True),
            reduced_cfg=dataclasses.replace(spec.reduced_cfg, scan_unroll=True),
        )
        builder = _lm_cell if spec.family == "lm" else _gnn_cell
        cell = builder(spec2, shape, mesh, reduced, variant)
    return cell
