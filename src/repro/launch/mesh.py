"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state.  Single pod: (data=16, model=16) = 256 chips (TPU v5e pod);
multi-pod: (pod=2, data=16, model=16) = 512 chips, the 'pod' axis carrying
pure data parallelism across the inter-pod (DCN) links.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serving_mesh(data: int = 4, model: int = 4):
    """Right-sized serving slice (default (4,4) = 16 chips).  Decode at
    production batch sizes is latency-bound on a 256-chip training mesh
    (EXPERIMENTS.md §Perf/H4); real serving deploys many small replicas —
    slice size picked per model by KV-cache footprint."""
    return jax.make_mesh((data, model), ("data", "model"))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (virtual) devices exist — tests/smoke."""
    return jax.make_mesh((data, model), ("data", "model"))
