"""Pallas TPU kernel: entropy-lane window refill (gather-based).

The lane-parallel entropy decoders (``repro.codecs.entropy``) advance one
bit cursor per lane and refill a window register from the bitstream every
step.  On the host that refill is a single numpy sliding-window gather; this
kernel is the device twin: for each lane it gathers the five bytes straddling
the cursor and stitches them into a 32-bit LSB-first window (32 bits is two
max-length Huffman codes' worth, and TPU lanes have no native 64-bit ints —
DESIGN.md §2, so the device window is half the host's 64-bit one).

The gather (``jnp.take``) *is* the kernel: entropy refill is bandwidth-bound,
which is why it is worth keeping on-device next to the rest of a fused decode
pipeline instead of round-tripping windows through the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 256  # lanes per grid step


def _refill_kernel(pos_ref, buf_ref, o_ref):
    w32 = buf_ref[...].astype(jnp.uint32)
    pos = pos_ref[...].astype(jnp.int32)
    byte0 = pos >> 3
    r = ((pos & 7).astype(jnp.uint32))
    b0 = jnp.take(w32, byte0)
    b1 = jnp.take(w32, byte0 + 1)
    b2 = jnp.take(w32, byte0 + 2)
    b3 = jnp.take(w32, byte0 + 3)
    b4 = jnp.take(w32, byte0 + 4)
    lo = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
    # (b4 << 1) << (31 - r) == b4 << (32 - r), well-defined at r == 0
    o_ref[...] = (lo >> r) | ((b4 << 1) << (jnp.uint32(31) - r))


def lane_refill_pallas(
    buf: jax.Array, bitpos: jax.Array, *, interpret: bool = True
) -> jax.Array:
    """(buf u8, padded past every cursor by >= 5 bytes; bitpos i32) -> u32."""
    n = bitpos.shape[0]
    assert n % BLOCK == 0, "caller pads lanes to BLOCK multiple"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _refill_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec(buf.shape, lambda i: (0,)),  # whole bitstream
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(bitpos, buf)
