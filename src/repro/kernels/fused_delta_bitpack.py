"""Pallas TPU kernel: FUSED delta + bitpack (beyond-paper optimization).

The paper's modular graph executes `delta` then `bitpack` as two codecs —
two HBM round-trips.  On TPU the stream transform is bandwidth-bound
(arithmetic intensity ≈ 0.5 flop/byte), so fusing them halves HBM traffic:

    baseline  : read x, write d      (delta)   + read d, write packed
              = 2n reads + n + n/per writes
    fused     : read x (+1 tail block), write packed
              ≈ n reads + n/per writes                (~2x traffic cut)

Encode-only fusion is lossless for monotone streams whose deltas fit `bits`
(sorted indices, offset tables — exactly the paper's delta use cases); the
ops.py wrapper verifies the precondition.  See EXPERIMENTS.md §Perf/K1.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_WORDS = 512


def _fused_encode_kernel(bits: int):
    per = 32 // bits
    mask = np.uint32((1 << bits) - 1)
    block_vals = BLOCK_WORDS * per

    def kernel(x_ref, xprev_ref, o_ref):
        shifts = jnp.arange(per, dtype=jnp.uint32) * np.uint32(bits)
        i = pl.program_id(0)
        x = x_ref[...]
        prev_last = jnp.where(i == 0, jnp.uint32(0), xprev_ref[block_vals - 1])
        shifted = jnp.concatenate([prev_last[None], x[:-1]])
        d = (x - shifted) & mask
        o_ref[...] = (d.reshape(BLOCK_WORDS, per) << shifts[None, :]).sum(
            axis=1, dtype=jnp.uint32
        )

    return kernel


def fused_delta_bitpack_pallas(
    x: jax.Array, bits: int, *, interpret: bool = True
) -> jax.Array:
    assert 32 % bits == 0
    per = 32 // bits
    n = x.shape[0]
    block_vals = BLOCK_WORDS * per
    assert n % block_vals == 0, "caller pads to block multiple"
    grid = (n // block_vals,)
    return pl.pallas_call(
        _fused_encode_kernel(bits),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_vals,), lambda i: (i,)),
            pl.BlockSpec((block_vals,), lambda i: (jnp.maximum(i - 1, 0),)),
        ],
        out_specs=pl.BlockSpec((BLOCK_WORDS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // per,), jnp.uint32),
        interpret=interpret,
    )(x, x)


def _fused_decode_sum_kernel(bits: int):
    per = 32 // bits
    mask = np.uint32((1 << bits) - 1)

    def kernel(w_ref, o_ref):
        shifts = jnp.arange(per, dtype=jnp.uint32) * np.uint32(bits)
        w = w_ref[...]
        d = ((w[:, None] >> shifts[None, :]) & mask).reshape(-1)
        o_ref[...] = jnp.sum(d, dtype=jnp.uint32)[None]

    return kernel


def _fused_decode_scan_kernel(bits: int):
    per = 32 // bits
    mask = np.uint32((1 << bits) - 1)

    def kernel(w_ref, carry_ref, o_ref):
        shifts = jnp.arange(per, dtype=jnp.uint32) * np.uint32(bits)
        w = w_ref[...]
        d = ((w[:, None] >> shifts[None, :]) & mask).reshape(-1)
        o_ref[...] = jnp.cumsum(d, dtype=jnp.uint32) + carry_ref[0]

    return kernel


def fused_delta_bitpack_decode_pallas(
    w: jax.Array, bits: int, *, interpret: bool = True
) -> jax.Array:
    """Fused unpack+scan decode: packed words are read twice (sum pass + scan
    pass) but the full-width delta stream never touches HBM at all."""
    assert 32 % bits == 0
    per = 32 // bits
    m = w.shape[0]
    assert m % BLOCK_WORDS == 0
    grid = (m // BLOCK_WORDS,)
    in_spec = pl.BlockSpec((BLOCK_WORDS,), lambda i: (i,))
    sums = pl.pallas_call(
        _fused_decode_sum_kernel(bits),
        grid=grid,
        in_specs=[in_spec],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m // BLOCK_WORDS,), jnp.uint32),
        interpret=interpret,
    )(w)
    carry = jnp.cumsum(sums, dtype=jnp.uint32) - sums
    return pl.pallas_call(
        _fused_decode_scan_kernel(bits),
        grid=grid,
        in_specs=[in_spec, pl.BlockSpec((1,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK_WORDS * per,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m * per,), jnp.uint32),
        interpret=interpret,
    )(w, carry)
