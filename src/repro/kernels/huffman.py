"""Pallas TPU kernels: canonical Huffman encode map + lane-parallel decode.

Encode on the device is table gathers plus bit packing: ``huffman_map``
turns symbols into (canonical code, length) pairs, and the shared
scatter-add packer (``ref.pack_bits`` / ops glue) places them at their
cumsum bit offsets.  The map kernel here is the gather; packing stays in
XLA (scatter-add has no Pallas win).

Decode is the lane-refill loop made device-resident: each lane gathers the
five bytes straddling its cursor, stitches a 32-bit LSB-first window
(lane_refill idiom), indexes the low 15 bits into the decode LUT, and
advances.  One symbol per refill — the host drains three per 64-bit window,
but decode output is the *symbols*, not the bitstream, so the twins agree
bit-exactly on everything wire-visible.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MAP_BLOCK = 2048  # symbols per grid step for the encode map
LANE_BLOCK = 256  # lanes per grid step for decode


def _map_kernel(x_ref, codes_ref, lens_ref, code_ref, nbit_ref):
    xi = x_ref[...].astype(jnp.int32)
    code_ref[...] = jnp.take(codes_ref[...].astype(jnp.uint32), xi)
    nbit_ref[...] = jnp.take(lens_ref[...].astype(jnp.int32), xi)


def huffman_map_pallas(
    x: jax.Array, codes: jax.Array, lens: jax.Array, *, interpret: bool = True
):
    """(x u8, codes u32[256], lens i32[256]) -> (code u32, nbits i32) per sym."""
    n = x.shape[0]
    assert n % MAP_BLOCK == 0, "caller pads symbols to MAP_BLOCK multiple"
    grid = (n // MAP_BLOCK,)
    return pl.pallas_call(
        _map_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((MAP_BLOCK,), lambda i: (i,)),
            pl.BlockSpec(codes.shape, lambda i: (0,)),  # whole code table
            pl.BlockSpec(lens.shape, lambda i: (0,)),  # whole length table
        ],
        out_specs=[
            pl.BlockSpec((MAP_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((MAP_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.uint32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(x, codes, lens)


def _decode_kernel(pos_ref, buf_ref, sym_ref, len_ref, o_ref, *, max_rem):
    w32 = buf_ref[...].astype(jnp.uint32)
    sym = sym_ref[...].astype(jnp.int32)
    lnt = len_ref[...].astype(jnp.int32)

    def step(i, pos):
        byte0 = pos >> 3
        r = (pos & 7).astype(jnp.uint32)
        b0 = jnp.take(w32, byte0)
        b1 = jnp.take(w32, byte0 + 1)
        b2 = jnp.take(w32, byte0 + 2)
        b3 = jnp.take(w32, byte0 + 3)
        b4 = jnp.take(w32, byte0 + 4)
        lo = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
        # (b4 << 1) << (31 - r) == b4 << (32 - r), well-defined at r == 0
        win = (lo >> r) | ((b4 << 1) << (jnp.uint32(31) - r))
        low = (win & jnp.uint32(0x7FFF)).astype(jnp.int32)
        o_ref[pl.ds(i, 1), :] = jnp.take(sym, low).astype(jnp.uint8)[None, :]
        return pos + jnp.take(lnt, low)

    jax.lax.fori_loop(0, max_rem, step, pos_ref[...].astype(jnp.int32))


def huffman_decode_pallas(
    buf: jax.Array,
    pos: jax.Array,
    lut_sym: jax.Array,
    lut_len: jax.Array,
    max_rem: int,
    *,
    interpret: bool = True,
):
    """(buf u8 padded >= 5 bytes past every cursor, pos i32 lane bit starts,
    lut_sym/lut_len 2^15 LUTs) -> (max_rem, n_lanes) u8 symbols."""
    n = pos.shape[0]
    assert n % LANE_BLOCK == 0, "caller pads lanes to LANE_BLOCK multiple"
    grid = (n // LANE_BLOCK,)
    return pl.pallas_call(
        functools.partial(_decode_kernel, max_rem=max_rem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec(buf.shape, lambda i: (0,)),  # whole bitstream
            pl.BlockSpec(lut_sym.shape, lambda i: (0,)),  # whole decode LUTs
            pl.BlockSpec(lut_len.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((max_rem, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((max_rem, n), jnp.uint8),
        interpret=interpret,
    )(pos, buf, lut_sym, lut_len)
