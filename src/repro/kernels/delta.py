"""Pallas TPU kernels: blocked delta encode/decode.

OpenZL's CPU delta kernel is a byte-serial scan.  The TPU adaptation
(DESIGN.md §2.2) splits the stream into VMEM-sized blocks:

  encode  — embarrassingly parallel; the cross-block neighbour is read from a
            second ref mapped to block i-1 (clamped at 0, masked).
  decode  — decoupled scan: (1) per-block sums, (2) tiny exclusive cumsum on
            the host program, (3) per-block inclusive scan + carry add.

All arithmetic is wrapping uint32 — bit-exact with the host numpy codec.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048  # 8 KiB of u32 per ref — comfortably inside 16 MiB VMEM


def _encode_kernel(x_ref, xprev_ref, o_ref):
    i = pl.program_id(0)
    x = x_ref[...]
    prev_last = jnp.where(i == 0, jnp.uint32(0), xprev_ref[BLOCK - 1])
    shifted = jnp.concatenate([prev_last[None], x[:-1]])
    o_ref[...] = x - shifted


def delta_encode_pallas(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            # the same array, mapped to the previous block (clamped at 0)
            pl.BlockSpec((BLOCK,), lambda i: (jnp.maximum(i - 1, 0),)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(x, x)


def _block_sum_kernel(x_ref, o_ref):
    o_ref[...] = jnp.sum(x_ref[...], dtype=jnp.uint32)[None]


def _scan_carry_kernel(x_ref, carry_ref, o_ref):
    o_ref[...] = jnp.cumsum(x_ref[...], dtype=jnp.uint32) + carry_ref[0]


def delta_decode_pallas(d: jax.Array, *, interpret: bool = True) -> jax.Array:
    n = d.shape[0]
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    sums = pl.pallas_call(
        _block_sum_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // BLOCK,), jnp.uint32),
        interpret=interpret,
    )(d)
    carry = jnp.cumsum(sums, dtype=jnp.uint32) - sums  # exclusive prefix
    return pl.pallas_call(
        _scan_carry_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(d, carry)
