"""Pallas TPU kernel: float plane split (checkpoint-compression hot path).

Splits uint32 float bit patterns into sign/exponent/mantissa planes in one
VMEM pass — the paper's §VIII checkpoint transform.  The multi-output
pallas_call produces all three planes from a single HBM read of the input.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK = 2048


def _split_kernel(exp_bits: int, man_bits: int):
    exp_mask = np.uint32((1 << exp_bits) - 1)
    man_mask = np.uint32((1 << man_bits) - 1)

    def kernel(u_ref, sign_ref, exp_ref, man_ref):
        u = u_ref[...]
        sign_ref[...] = (u >> (exp_bits + man_bits)).astype(jnp.uint8)
        exp_ref[...] = ((u >> man_bits) & exp_mask).astype(jnp.uint16)
        man_ref[...] = u & man_mask

    return kernel


def _merge_kernel(exp_bits: int, man_bits: int):
    def kernel(sign_ref, exp_ref, man_ref, u_ref):
        u_ref[...] = (
            (sign_ref[...].astype(jnp.uint32) << (exp_bits + man_bits))
            | (exp_ref[...].astype(jnp.uint32) << man_bits)
            | man_ref[...]
        )

    return kernel


def float_split_pallas(
    u: jax.Array, exp_bits: int, man_bits: int, *, interpret: bool = True
):
    n = u.shape[0]
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _split_kernel(exp_bits, man_bits),
        grid=grid,
        in_specs=[spec],
        out_specs=(spec, spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.uint16),
            jax.ShapeDtypeStruct((n,), jnp.uint32),
        ),
        interpret=interpret,
    )(u)


def float_merge_pallas(
    sign: jax.Array,
    exp: jax.Array,
    man: jax.Array,
    exp_bits: int,
    man_bits: int,
    *,
    interpret: bool = True,
):
    n = sign.shape[0]
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _merge_kernel(exp_bits, man_bits),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=interpret,
    )(sign, exp, man)
