"""Pallas TPU kernel: byte-plane shuffle (the `transpose` codec hot path).

(n, w) uint8 records -> (w, n) planes.  Tiled so each grid step transposes a
(BLOCK, w) VMEM tile into a (w, BLOCK) slab of the output — the classic
blocked transpose, with the record width w kept whole per tile (w <= 8 for
numeric streams, so a tile is ~16 KiB).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048


def _shuffle_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].T


def byteshuffle_pallas(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    """x: (n, w) uint8 with n % BLOCK == 0 -> (w, n) uint8."""
    n, w = x.shape
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _shuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((w, BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((w, n), jnp.uint8),
        interpret=interpret,
    )(x)


def byteunshuffle_pallas(p: jax.Array, *, interpret: bool = True) -> jax.Array:
    """p: (w, n) uint8 planes -> (n, w) records (inverse)."""
    w, n = p.shape
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _shuffle_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((w, BLOCK), lambda i: (0, i))],
        out_specs=pl.BlockSpec((BLOCK, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, w), jnp.uint8),
        interpret=interpret,
    )(p)
