# Pallas TPU kernels for the codec hot-spots OpenZL optimizes in C
# (DESIGN.md §2): delta, byteshuffle (transpose), bitpack, histogram,
# float_split, and the beyond-paper fused_delta_bitpack.  Each kernel module
# holds the pl.pallas_call + BlockSpec tiling; ops.py is the jit'd public
# wrapper; ref.py is the pure-jnp oracle the tests sweep against.
from . import ops, ref  # noqa: F401
