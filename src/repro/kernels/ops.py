"""Jit'd public wrappers around the Pallas kernels.

Handles capacity padding (XLA static shapes — DESIGN.md §2.1), backend
selection (`use_pallas=False` falls back to the jnp oracle in ref.py), and
the lossless-precondition checks for the fused kernel.

On this CPU container Pallas executes in interpret mode; on TPU the same
calls compile to Mosaic.  `interpret` is resolved from the backend.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .bitpack import BLOCK_WORDS, bitpack_pallas, bitunpack_pallas
from .byteshuffle import BLOCK as SHUF_BLOCK, byteshuffle_pallas, byteunshuffle_pallas
from .delta import BLOCK as DELTA_BLOCK, delta_decode_pallas, delta_encode_pallas
from .float_split import BLOCK as FS_BLOCK, float_merge_pallas, float_split_pallas
from .fused_delta_bitpack import (
    fused_delta_bitpack_decode_pallas,
    fused_delta_bitpack_pallas,
)
from .histogram import BLOCK as HIST_BLOCK, histogram_pallas


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, multiple: int) -> jax.Array:
    n = x.shape[0]
    pad = (-n) % multiple
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.zeros((pad,) + x.shape[1:], x.dtype)])


# --------------------------------------------------------------------- delta
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def delta_encode(x: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    x = x.astype(jnp.uint32)
    if x.shape[0] == 0:
        return x
    if not use_pallas:
        return ref.delta_encode(x)
    n = x.shape[0]
    out = delta_encode_pallas(_pad_to(x, DELTA_BLOCK), interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def delta_decode(d: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    d = d.astype(jnp.uint32)
    if d.shape[0] == 0:
        return d
    if not use_pallas:
        return ref.delta_decode(d)
    n = d.shape[0]
    out = delta_decode_pallas(_pad_to(d, DELTA_BLOCK), interpret=_interpret())
    return out[:n]


# --------------------------------------------------------------- byteshuffle
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def byteshuffle(x: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """(n, w) uint8 -> (w, n)."""
    if x.shape[0] == 0:
        return x.T
    if not use_pallas:
        return ref.byteshuffle_encode(x)
    n = x.shape[0]
    out = byteshuffle_pallas(_pad_to(x, SHUF_BLOCK), interpret=_interpret())
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def byteunshuffle(p: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """(w, n) uint8 -> (n, w)."""
    if p.shape[1] == 0:
        return p.T
    if not use_pallas:
        return ref.byteshuffle_decode(p)
    w, n = p.shape
    pad = (-n) % SHUF_BLOCK
    if pad:
        p = jnp.concatenate([p, jnp.zeros((w, pad), p.dtype)], axis=1)
    out = byteunshuffle_pallas(p, interpret=_interpret())
    return out[:n]


# ------------------------------------------------------------------- bitpack
@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def bitpack(x: jax.Array, bits: int, *, use_pallas: bool = True) -> jax.Array:
    """Returns packed words for ceil(n/per) values; caller tracks n."""
    x = x.astype(jnp.uint32)
    per = 32 // bits
    n = x.shape[0]
    n_words = -(-n // per)
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    if not use_pallas:
        return ref.bitpack_encode(_pad_to(x, per), bits)[:n_words]
    out = bitpack_pallas(_pad_to(x, BLOCK_WORDS * per), bits, interpret=_interpret())
    return out[:n_words]


@functools.partial(jax.jit, static_argnames=("bits", "n", "use_pallas"))
def bitunpack(w: jax.Array, bits: int, n: int, *, use_pallas: bool = True) -> jax.Array:
    if w.shape[0] == 0:
        return jnp.zeros((n,), jnp.uint32)
    if not use_pallas:
        return ref.bitpack_decode(w, bits)[:n]
    out = bitunpack_pallas(_pad_to(w, BLOCK_WORDS), bits, interpret=_interpret())
    return out[:n]


# ----------------------------------------------------------------- histogram
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def histogram(x: jax.Array, *, use_pallas: bool = True) -> jax.Array:
    """256-bin counts of uint8 symbols.  Padding adds to bin 0; corrected."""
    x = x.astype(jnp.uint8)
    n = x.shape[0]
    if n == 0:
        return jnp.zeros((256,), jnp.int32)
    if not use_pallas:
        return ref.histogram(x)
    pad = (-n) % HIST_BLOCK
    counts = histogram_pallas(_pad_to(x, HIST_BLOCK), interpret=_interpret())
    return counts.at[0].add(-pad)


# --------------------------------------------------------------- float_split
@functools.partial(jax.jit, static_argnames=("exp_bits", "man_bits", "use_pallas"))
def float_split(u: jax.Array, exp_bits: int, man_bits: int, *, use_pallas: bool = True):
    u = u.astype(jnp.uint32)
    if u.shape[0] == 0:
        return ref.float_split_encode(u, exp_bits, man_bits)
    if not use_pallas:
        return ref.float_split_encode(u, exp_bits, man_bits)
    n = u.shape[0]
    sign, exp, man = float_split_pallas(
        _pad_to(u, FS_BLOCK), exp_bits, man_bits, interpret=_interpret()
    )
    return sign[:n], exp[:n], man[:n]


@functools.partial(jax.jit, static_argnames=("exp_bits", "man_bits", "use_pallas"))
def float_merge(sign, exp, man, exp_bits: int, man_bits: int, *, use_pallas: bool = True):
    if sign.shape[0] == 0:
        return ref.float_split_decode(sign, exp, man, exp_bits, man_bits)
    if not use_pallas:
        return ref.float_split_decode(sign, exp, man, exp_bits, man_bits)
    n = sign.shape[0]
    out = float_merge_pallas(
        _pad_to(sign, FS_BLOCK),
        _pad_to(exp, FS_BLOCK),
        _pad_to(man, FS_BLOCK),
        exp_bits,
        man_bits,
        interpret=_interpret(),
    )
    return out[:n]


# ------------------------------------------------- fused delta+bitpack (K1)
def fused_delta_bitpack_fits(x: jax.Array, bits: int) -> jax.Array:
    """Lossless precondition: every wrapped delta fits in `bits`."""
    d = ref.delta_encode(x.astype(jnp.uint32))
    return jnp.all(d < jnp.uint32(1 << bits))


@functools.partial(jax.jit, static_argnames=("bits", "use_pallas"))
def fused_delta_bitpack(x: jax.Array, bits: int, *, use_pallas: bool = True):
    x = x.astype(jnp.uint32)
    per = 32 // bits
    n = x.shape[0]
    n_words = -(-n // per)
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    if not use_pallas:
        return ref.fused_delta_bitpack_encode(_pad_to(x, per), bits)[:n_words]
    # pad by REPEATING the last value so padded deltas are 0 (still fit)
    pad = (-n) % (BLOCK_WORDS * per)
    if pad and n:
        x = jnp.concatenate([x, jnp.broadcast_to(x[-1], (pad,))])
    elif pad:
        x = jnp.zeros(pad, jnp.uint32)
    out = fused_delta_bitpack_pallas(x, bits, interpret=_interpret())
    return out[:n_words]


@functools.partial(jax.jit, static_argnames=("bits", "n", "use_pallas"))
def fused_delta_bitpack_decode(w: jax.Array, bits: int, n: int, *, use_pallas: bool = True):
    if w.shape[0] == 0:
        return jnp.zeros((n,), jnp.uint32)
    if not use_pallas:
        return ref.fused_delta_bitpack_decode(w, bits)[:n]
    out = fused_delta_bitpack_decode_pallas(
        _pad_to(w, BLOCK_WORDS), bits, interpret=_interpret()
    )
    return out[:n]


# ---------------------------------------------------------- entropy: huffman
@functools.partial(jax.jit, static_argnames=())
def histogram_exact(x: jax.Array) -> jax.Array:
    """256-bin counts with integer accumulation — exact at any stream size.

    The MXU ``histogram`` kernel is f32 and only exact below 2^24 per bin;
    entropy-coder table construction needs exact counts, so the device twins
    use this (scatter-add on both backends — no Pallas variant needed)."""
    return ref.histogram_exact(x.astype(jnp.uint8))


@functools.partial(jax.jit, static_argnames=("total_bytes",))
def pack_bits(vals: jax.Array, offs: jax.Array, total_bytes: int) -> jax.Array:
    """Scatter-add bit packer (see ref.pack_bits): bit-identical to the host
    bit-matrix writer.  ``total_bytes`` is static — callers pass a bucketed
    capacity and trim, so content-dependent sizes don't recompile."""
    return ref.pack_bits(vals.astype(jnp.uint32), offs.astype(jnp.int32), total_bytes)


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def huffman_map(x: jax.Array, codes: jax.Array, lens: jax.Array, *, use_pallas: bool = True):
    """Symbols -> (canonical code u32, nbits i32, exclusive bit offs i32[n+1]).

    ``offs[-1]`` is the total bit count; the cumsum stays int32, so callers
    gate stream size at <= 2^27 symbols (15 bits/code max)."""
    from .huffman import MAP_BLOCK, huffman_map_pallas

    x = x.astype(jnp.uint8)
    codes = codes.astype(jnp.uint32)
    lens = lens.astype(jnp.int32)
    n = x.shape[0]
    if n == 0:
        z = jnp.zeros((0,), jnp.uint32)
        return z, z.astype(jnp.int32), jnp.zeros((1,), jnp.int32)
    if use_pallas:
        code, nb = huffman_map_pallas(
            _pad_to(x, MAP_BLOCK), codes, lens, interpret=_interpret()
        )
        code, nb = code[:n], nb[:n]
    else:
        code, nb = ref.huffman_map(x, codes, lens)
    offs = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(nb, dtype=jnp.int32)]
    )
    return code, nb, offs


@functools.partial(jax.jit, static_argnames=("max_rem", "use_pallas"))
def huffman_decode(
    buf: jax.Array,
    pos: jax.Array,
    lut_sym: jax.Array,
    lut_len: jax.Array,
    max_rem: int,
    *,
    use_pallas: bool = True,
):
    """Lane-parallel Huffman decode -> (max_rem, n_lanes) u8 symbols.

    ``buf`` must be padded so every cursor has the host decoder's overrun
    room; surplus rows of short lanes are pad garbage the caller trims."""
    from .huffman import LANE_BLOCK, huffman_decode_pallas

    buf = buf.astype(jnp.uint8)
    n = pos.shape[0]
    if n == 0 or max_rem == 0:
        return jnp.zeros((max_rem, n), jnp.uint8)
    if not use_pallas:
        return ref.huffman_decode_lanes(buf, pos, lut_sym, lut_len, max_rem)
    out = huffman_decode_pallas(
        buf,
        _pad_to(pos.astype(jnp.int32), LANE_BLOCK),
        lut_sym.astype(jnp.int32),
        lut_len.astype(jnp.int32),
        max_rem,
        interpret=_interpret(),
    )
    return out[:, :n]


# -------------------------------------------------------------- entropy: fse
@functools.partial(jax.jit, static_argnames=("width", "total", "use_pallas"))
def fse_encode(
    lanesT: jax.Array,
    rem: jax.Array,
    nb0: jax.Array,
    thr: jax.Array,
    st0: jax.Array,
    norm: jax.Array,
    enc_flat: jax.Array,
    width: int,
    total: int,
    *,
    use_pallas: bool = True,
):
    """tANS backward scan + wire-layout bit offsets.

    Returns (vals u32 planes, global bit offsets i32 planes, final states,
    per-lane bit lengths, lane byte offsets i32[n+1]).  The offsets place
    every emission directly into the *concatenated* per-lane bitstream
    layout the host encoder produces, so one ``pack_bits`` call yields the
    final wire bytes."""
    from .fse import LANE_BLOCK, fse_encode_pallas

    max_rem, n = lanesT.shape
    rem = rem.astype(jnp.int32)
    if use_pallas:
        pad = (-n) % LANE_BLOCK
        if pad:
            lanesT = jnp.concatenate(
                [lanesT, jnp.zeros((max_rem, pad), lanesT.dtype)], axis=1
            )
        vals, nbs, state = fse_encode_pallas(
            lanesT,
            _pad_to(rem, LANE_BLOCK),
            nb0.astype(jnp.int32),
            thr.astype(jnp.int32),
            st0.astype(jnp.int32),
            norm.astype(jnp.int32),
            enc_flat.astype(jnp.int32),
            width,
            total,
            interpret=_interpret(),
        )
        vals, nbs, state = vals[:, :n], nbs[:, :n], state[:n]
    else:
        vals, nbs, state = ref.fse_encode_lanes(
            lanesT, rem, nb0, thr, st0, norm, enc_flat, width, total
        )
    bitpos = jnp.sum(nbs, axis=0, dtype=jnp.int32)
    # emission order is decreasing position i, so the offset of emission i
    # within its lane is the suffix sum of later positions' bit counts
    suffix = jnp.cumsum(nbs[::-1], axis=0, dtype=jnp.int32)[::-1]
    intra = suffix - nbs
    nbytes = (bitpos + 7) >> 3
    byte_off = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(nbytes, dtype=jnp.int32)]
    )
    goffs = byte_off[None, :-1] * 8 + intra
    return vals, goffs, state, bitpos, byte_off


@functools.partial(jax.jit, static_argnames=("max_rem", "use_pallas"))
def fse_decode(
    flat: jax.Array,
    lane_base: jax.Array,
    bitlen: jax.Array,
    state0: jax.Array,
    dec_sym: jax.Array,
    dec_nb: jax.Array,
    dec_base: jax.Array,
    max_rem: int,
    *,
    use_pallas: bool = True,
):
    """Lane-parallel tANS decode -> (max_rem, n_lanes) u8 symbols."""
    from .fse import LANE_BLOCK, fse_decode_pallas

    flat = flat.astype(jnp.uint8)
    n = bitlen.shape[0]
    if n == 0 or max_rem == 0:
        return jnp.zeros((max_rem, n), jnp.uint8)
    if not use_pallas:
        return ref.fse_decode_lanes(
            flat, lane_base, bitlen, state0, dec_sym, dec_nb, dec_base, max_rem
        )
    out = fse_decode_pallas(
        flat,
        _pad_to(lane_base.astype(jnp.int32), LANE_BLOCK),
        _pad_to(bitlen.astype(jnp.int32), LANE_BLOCK),
        _pad_to(state0.astype(jnp.int32), LANE_BLOCK),
        dec_sym.astype(jnp.int32),
        dec_nb.astype(jnp.int32),
        dec_base.astype(jnp.int32),
        max_rem,
        interpret=_interpret(),
    )
    return out[:, :n]


# --------------------------------------------------------------- lane refill
@functools.partial(jax.jit, static_argnames=("use_pallas",))
def lane_refill(buf: jax.Array, bitpos: jax.Array, *, use_pallas: bool = True):
    """Entropy-lane window refill: next 32 bits per lane bit-cursor, u32.

    The device-side building block of the entropy decoders' gather refill
    (``repro.codecs.entropy`` lane-refill scheme).  ``buf`` must be padded
    so every cursor has >= 5 readable bytes; lanes are padded to the kernel
    block internally.  Bit-exact with the numpy host path (tests).
    """
    from .lane_refill import BLOCK as REFILL_BLOCK, lane_refill_pallas

    n = bitpos.shape[0]
    if n == 0:
        return jnp.zeros((0,), jnp.uint32)
    buf = buf.astype(jnp.uint8)
    if not use_pallas:
        return ref.lane_refill(buf, bitpos)
    pos = _pad_to(bitpos.astype(jnp.int32), REFILL_BLOCK)
    out = lane_refill_pallas(buf, pos, interpret=_interpret())
    return out[:n]
