"""Pallas TPU kernels: tANS (FSE) interleaved-state encode scan + decode.

State machine after the SCL FSE exemplar and the host coder
(``repro.codecs.entropy``): encode walks each lane *backward*, carrying an
int32 state in [0, 2*2^table_log); a lane of length r initializes its state
at position r-1 and, for every earlier position, emits the low
``nb0[s] - (X < thr[s])`` bits of ``X = state + total`` before stepping
through the flattened encode table.  The kernel produces the per-position
(value, nbits) planes plus final states; bit I/O composition (suffix-sum
offsets + the scatter-add packer) is XLA glue in ops.py — placing values
directly into the concatenated wire layout.

Decode is the forward walk: emit ``dec_sym[state]``, retreat the bit cursor,
refill a 32-bit window from the per-lane padded buffer (lane_refill gather
idiom) and gather the next state.  Exhausted lanes walk garbage states over
the zero pad — always in-table, trimmed by the caller, exactly like the
host's mask-free loop.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE_BLOCK = 256  # lanes per grid step


def _encode_kernel(
    lanesT_ref,
    rem_ref,
    nb0_ref,
    thr_ref,
    st0_ref,
    norm_ref,
    enc_ref,
    val_ref,
    nbs_ref,
    state_ref,
    *,
    width,
    total,
    max_rem,
):
    rem = rem_ref[...].astype(jnp.int32)
    nb0 = nb0_ref[...]
    thr = thr_ref[...]
    st0 = st0_ref[...]
    norm = norm_ref[...]
    enc = enc_ref[...]

    def step(j, state):
        i = max_rem - 1 - j
        s = lanesT_ref[pl.ds(i, 1), :].reshape(-1).astype(jnp.int32)
        emit = rem > i + 1
        X = state + total
        nb = jnp.take(nb0, s) - (X < jnp.take(thr, s)).astype(jnp.int32)
        nbe = jnp.where(emit, nb, 0)
        val = X.astype(jnp.uint32) & (
            (jnp.uint32(1) << nbe.astype(jnp.uint32)) - jnp.uint32(1)
        )
        val_ref[pl.ds(i, 1), :] = val[None, :]
        nbs_ref[pl.ds(i, 1), :] = nbe[None, :]
        xprime = jnp.clip((X >> nb) - jnp.take(norm, s), 0, width - 1)
        new_state = jnp.take(enc, s * width + xprime)
        return jnp.where(
            emit, new_state, jnp.where(rem == i + 1, jnp.take(st0, s), state)
        )

    state_ref[...] = jax.lax.fori_loop(
        0, max_rem, step, jnp.zeros(rem.shape, jnp.int32)
    )


def fse_encode_pallas(
    lanesT: jax.Array,
    rem: jax.Array,
    nb0: jax.Array,
    thr: jax.Array,
    st0: jax.Array,
    norm: jax.Array,
    enc_flat: jax.Array,
    width: int,
    total: int,
    *,
    interpret: bool = True,
):
    """(lanesT u8 (max_rem, n_lanes), rem i32, per-symbol tables i32[256],
    enc_flat i32) -> (vals u32, nbits i32) planes + final lane states i32."""
    max_rem, n = lanesT.shape
    assert n % LANE_BLOCK == 0, "caller pads lanes to LANE_BLOCK multiple"
    grid = (n // LANE_BLOCK,)
    tab = lambda a: pl.BlockSpec(a.shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(
            _encode_kernel, width=width, total=total, max_rem=max_rem
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((max_rem, LANE_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
            tab(nb0),
            tab(thr),
            tab(st0),
            tab(norm),
            tab(enc_flat),
        ],
        out_specs=[
            pl.BlockSpec((max_rem, LANE_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((max_rem, LANE_BLOCK), lambda i: (0, i)),
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((max_rem, n), jnp.uint32),
            jax.ShapeDtypeStruct((max_rem, n), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(lanesT, rem, nb0, thr, st0, norm, enc_flat)


def _decode_kernel(
    lane_base_ref,
    bitlen_ref,
    state0_ref,
    flat_ref,
    sym_ref,
    nb_ref,
    base_ref,
    o_ref,
    *,
    max_rem,
):
    w32 = flat_ref[...].astype(jnp.uint32)
    sym = sym_ref[...].astype(jnp.int32)
    nbt = nb_ref[...]
    bst = base_ref[...]
    lane_base = lane_base_ref[...].astype(jnp.int32)

    def step(i, carry):
        state, cursor = carry
        o_ref[pl.ds(i, 1), :] = jnp.take(sym, state).astype(jnp.uint8)[None, :]
        nb = jnp.take(nbt, state)
        base = jnp.take(bst, state)
        cursor = cursor - nb
        byte0 = lane_base + jnp.maximum(cursor >> 3, 0)
        r = (cursor & 7).astype(jnp.uint32)
        b0 = jnp.take(w32, byte0)
        b1 = jnp.take(w32, byte0 + 1)
        b2 = jnp.take(w32, byte0 + 2)
        b3 = jnp.take(w32, byte0 + 3)
        b4 = jnp.take(w32, byte0 + 4)
        lo = b0 | (b1 << 8) | (b2 << 16) | (b3 << 24)
        win = (lo >> r) | ((b4 << 1) << (jnp.uint32(31) - r))
        bits = win & ((jnp.uint32(1) << nb.astype(jnp.uint32)) - jnp.uint32(1))
        return base + bits.astype(jnp.int32), cursor

    jax.lax.fori_loop(
        0,
        max_rem,
        step,
        (state0_ref[...].astype(jnp.int32), bitlen_ref[...].astype(jnp.int32)),
    )


def fse_decode_pallas(
    flat: jax.Array,
    lane_base: jax.Array,
    bitlen: jax.Array,
    state0: jax.Array,
    dec_sym: jax.Array,
    dec_nb: jax.Array,
    dec_base: jax.Array,
    max_rem: int,
    *,
    interpret: bool = True,
):
    """(flat u8 concatenated per-lane padded buffers, lane_base i32 byte
    offsets, bitlen i32 bit lengths, state0 i32 final states, decode tables
    2^table_log) -> (max_rem, n_lanes) u8 symbols."""
    n = bitlen.shape[0]
    assert n % LANE_BLOCK == 0, "caller pads lanes to LANE_BLOCK multiple"
    grid = (n // LANE_BLOCK,)
    tab = lambda a: pl.BlockSpec(a.shape, lambda i: (0,))
    return pl.pallas_call(
        functools.partial(_decode_kernel, max_rem=max_rem),
        grid=grid,
        in_specs=[
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
            pl.BlockSpec((LANE_BLOCK,), lambda i: (i,)),
            tab(flat),
            tab(dec_sym),
            tab(dec_nb),
            tab(dec_base),
        ],
        out_specs=pl.BlockSpec((max_rem, LANE_BLOCK), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((max_rem, n), jnp.uint8),
        interpret=interpret,
    )(lane_base, bitlen, state0, flat, dec_sym, dec_nb, dec_base)
