"""Pallas TPU kernel: k-bit pack/unpack over uint32 words.

TPU restriction (DESIGN.md §2): k must divide 32 so values never straddle a
word — the pack is then a reshape + shift + lane-reduce, a pure VPU op with
no cross-lane bit carries.  The host codec keeps arbitrary-k support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

BLOCK_WORDS = 512  # output words per grid step


def _pack_kernel(bits: int):
    per = 32 // bits

    def kernel(x_ref, o_ref):
        # iota built in-kernel: pallas_call kernels may not capture tracers
        shifts = (jnp.arange(per, dtype=jnp.uint32) * np.uint32(bits))
        v = x_ref[...].reshape(BLOCK_WORDS, per)
        o_ref[...] = (v << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)

    return kernel


def _unpack_kernel(bits: int):
    per = 32 // bits
    mask = np.uint32((1 << bits) - 1)

    def kernel(w_ref, o_ref):
        shifts = (jnp.arange(per, dtype=jnp.uint32) * np.uint32(bits))
        w = w_ref[...]
        o_ref[...] = ((w[:, None] >> shifts[None, :]) & mask).reshape(-1)

    return kernel


def bitpack_pallas(x: jax.Array, bits: int, *, interpret: bool = True) -> jax.Array:
    assert 32 % bits == 0, "TPU bitpack: bits must divide 32"
    per = 32 // bits
    n = x.shape[0]
    block_vals = BLOCK_WORDS * per
    assert n % block_vals == 0, "caller pads to block multiple"
    grid = (n // block_vals,)
    return pl.pallas_call(
        _pack_kernel(bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_vals,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK_WORDS,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n // per,), jnp.uint32),
        interpret=interpret,
    )(x)


def bitunpack_pallas(w: jax.Array, bits: int, *, interpret: bool = True) -> jax.Array:
    assert 32 % bits == 0
    per = 32 // bits
    m = w.shape[0]
    assert m % BLOCK_WORDS == 0, "caller pads to block multiple"
    grid = (m // BLOCK_WORDS,)
    return pl.pallas_call(
        _unpack_kernel(bits),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_WORDS,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK_WORDS * per,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((m * per,), jnp.uint32),
        interpret=interpret,
    )(w)
