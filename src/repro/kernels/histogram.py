"""Pallas TPU kernel: 256-bin histogram via one-hot MXU contraction.

Scatter-increment histograms are hostile to TPUs (no fast random-access
scatter).  The TPU-native trick (DESIGN.md §2.5): build the one-hot matrix
of a symbol block and contract it with a ones vector on the MXU.  The
accumulator output ref is revisited by every grid step (out index_map is
constant), initialised at step 0.

Feeds Huffman/FSE table construction and the trainer's entropy estimator.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 4096


def _hist_kernel(x_ref, o_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.int32)
    one_hot = (x[:, None] == jnp.arange(256, dtype=jnp.int32)[None, :]).astype(
        jnp.float32
    )
    # ones @ one_hot : a (1,BLOCK)x(BLOCK,256) MXU contraction
    partial = jnp.dot(
        jnp.ones((BLOCK,), jnp.float32), one_hot, preferred_element_type=jnp.float32
    )
    o_ref[...] += partial.astype(jnp.int32)


def histogram_pallas(x: jax.Array, *, interpret: bool = True) -> jax.Array:
    n = x.shape[0]
    assert n % BLOCK == 0, "caller pads to BLOCK multiple"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _hist_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.int32),
        interpret=interpret,
    )(x)
