"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the Pallas kernels must match them bit-exactly
(tests sweep shapes/dtypes and assert equality).  They are also the fallback
implementation on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- delta
def delta_encode(x: jax.Array) -> jax.Array:
    """out[0] = x[0]; out[i] = x[i] - x[i-1]  (wrapping, unsigned)."""
    return jnp.concatenate([x[:1], x[1:] - x[:-1]])


def delta_decode(d: jax.Array) -> jax.Array:
    return jnp.cumsum(d, dtype=d.dtype)


# --------------------------------------------------------------- byteshuffle
def byteshuffle_encode(x: jax.Array) -> jax.Array:
    """(n, w) uint8 records -> (w, n) byte planes (Blosc shuffle)."""
    return x.T


def byteshuffle_decode(p: jax.Array) -> jax.Array:
    return p.T


# ------------------------------------------------------------------- bitpack
def bitpack_encode(x: jax.Array, bits: int) -> jax.Array:
    """Pack uint32 values (< 2^bits) into uint32 words, LSB-first.

    bits must divide 32 (TPU variant restriction; the host codec supports
    arbitrary widths).  x.size must be a multiple of 32//bits.
    """
    per = 32 // bits
    v = x.reshape(-1, per).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    return (v << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)


def bitpack_decode(w: jax.Array, bits: int) -> jax.Array:
    per = 32 // bits
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    return ((w[:, None] >> shifts[None, :]) & mask).reshape(-1)


# ----------------------------------------------------------------- histogram
def histogram(x: jax.Array) -> jax.Array:
    """256-bin histogram of uint8 symbols -> int32 counts."""
    one_hot = (x[:, None] == jnp.arange(256, dtype=x.dtype)[None, :]).astype(
        jnp.float32
    )
    # MXU form: ones-vector contraction (see DESIGN.md §2.5)
    counts = jnp.dot(jnp.ones((x.shape[0],), jnp.float32), one_hot)
    return counts.astype(jnp.int32)


# --------------------------------------------------------------- float_split
def float_split_encode(u: jax.Array, exp_bits: int, man_bits: int):
    """uint bit patterns -> (sign u8, exponent u8/u16, mantissa u32)."""
    u = u.astype(jnp.uint32)
    sign = (u >> (exp_bits + man_bits)).astype(jnp.uint8)
    exp_mask = jnp.uint32((1 << exp_bits) - 1)
    man_mask = jnp.uint32((1 << man_bits) - 1)
    exp = ((u >> man_bits) & exp_mask).astype(jnp.uint16)
    man = (u & man_mask).astype(jnp.uint32)
    return sign, exp, man


def float_split_decode(sign, exp, man, exp_bits: int, man_bits: int):
    u = (
        (sign.astype(jnp.uint32) << (exp_bits + man_bits))
        | (exp.astype(jnp.uint32) << man_bits)
        | man.astype(jnp.uint32)
    )
    return u


# ------------------------------------------------- fused delta+bitpack (v3)
def fused_delta_bitpack_encode(x: jax.Array, bits: int) -> jax.Array:
    """Beyond-paper fusion: one pass instead of two HBM round-trips."""
    return bitpack_encode(delta_encode(x) & jnp.uint32((1 << bits) - 1), bits)


def fused_delta_bitpack_decode(w: jax.Array, bits: int) -> jax.Array:
    # NOTE: only lossless when all deltas fit in `bits` (checked by caller)
    return delta_decode(bitpack_decode(w, bits))


# --------------------------------------------------------------- lane refill
def lane_refill(buf: jax.Array, bitpos: jax.Array) -> jax.Array:
    """Entropy-lane window refill: next 32 bits at each lane's bit cursor.

    ``buf`` is the (padded) bitstream as uint8; the result is the LSB-first
    32-bit window a lane decoder consumes next.  Device twin of the numpy
    sliding-window gather in ``repro.codecs.entropy`` (32-bit because TPU
    lanes have no native 64-bit ints).
    """
    w32 = buf.astype(jnp.uint32)
    byte0 = bitpos.astype(jnp.int32) >> 3
    r = (bitpos.astype(jnp.int32) & 7).astype(jnp.uint32)
    b = [jnp.take(w32, byte0 + k) for k in range(5)]
    lo = b[0] | (b[1] << 8) | (b[2] << 16) | (b[3] << 24)
    return (lo >> r) | ((b[4] << 1) << (jnp.uint32(31) - r))
