"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the Pallas kernels must match them bit-exactly
(tests sweep shapes/dtypes and assert equality).  They are also the fallback
implementation on backends without Pallas support.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------- delta
def delta_encode(x: jax.Array) -> jax.Array:
    """out[0] = x[0]; out[i] = x[i] - x[i-1]  (wrapping, unsigned)."""
    return jnp.concatenate([x[:1], x[1:] - x[:-1]])


def delta_decode(d: jax.Array) -> jax.Array:
    return jnp.cumsum(d, dtype=d.dtype)


# --------------------------------------------------------------- byteshuffle
def byteshuffle_encode(x: jax.Array) -> jax.Array:
    """(n, w) uint8 records -> (w, n) byte planes (Blosc shuffle)."""
    return x.T


def byteshuffle_decode(p: jax.Array) -> jax.Array:
    return p.T


# ------------------------------------------------------------------- bitpack
def bitpack_encode(x: jax.Array, bits: int) -> jax.Array:
    """Pack uint32 values (< 2^bits) into uint32 words, LSB-first.

    bits must divide 32 (TPU variant restriction; the host codec supports
    arbitrary widths).  x.size must be a multiple of 32//bits.
    """
    per = 32 // bits
    v = x.reshape(-1, per).astype(jnp.uint32)
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    return (v << shifts[None, :]).sum(axis=1, dtype=jnp.uint32)


def bitpack_decode(w: jax.Array, bits: int) -> jax.Array:
    per = 32 // bits
    shifts = (jnp.arange(per, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    mask = jnp.uint32((1 << bits) - 1)
    return ((w[:, None] >> shifts[None, :]) & mask).reshape(-1)


# ----------------------------------------------------------------- histogram
def histogram(x: jax.Array) -> jax.Array:
    """256-bin histogram of uint8 symbols -> int32 counts."""
    one_hot = (x[:, None] == jnp.arange(256, dtype=x.dtype)[None, :]).astype(
        jnp.float32
    )
    # MXU form: ones-vector contraction (see DESIGN.md §2.5)
    counts = jnp.dot(jnp.ones((x.shape[0],), jnp.float32), one_hot)
    return counts.astype(jnp.int32)


# --------------------------------------------------------------- float_split
def float_split_encode(u: jax.Array, exp_bits: int, man_bits: int):
    """uint bit patterns -> (sign u8, exponent u8/u16, mantissa u32)."""
    u = u.astype(jnp.uint32)
    sign = (u >> (exp_bits + man_bits)).astype(jnp.uint8)
    exp_mask = jnp.uint32((1 << exp_bits) - 1)
    man_mask = jnp.uint32((1 << man_bits) - 1)
    exp = ((u >> man_bits) & exp_mask).astype(jnp.uint16)
    man = (u & man_mask).astype(jnp.uint32)
    return sign, exp, man


def float_split_decode(sign, exp, man, exp_bits: int, man_bits: int):
    u = (
        (sign.astype(jnp.uint32) << (exp_bits + man_bits))
        | (exp.astype(jnp.uint32) << man_bits)
        | man.astype(jnp.uint32)
    )
    return u


# ------------------------------------------------- fused delta+bitpack (v3)
def fused_delta_bitpack_encode(x: jax.Array, bits: int) -> jax.Array:
    """Beyond-paper fusion: one pass instead of two HBM round-trips."""
    return bitpack_encode(delta_encode(x) & jnp.uint32((1 << bits) - 1), bits)


def fused_delta_bitpack_decode(w: jax.Array, bits: int) -> jax.Array:
    # NOTE: only lossless when all deltas fit in `bits` (checked by caller)
    return delta_decode(bitpack_decode(w, bits))


# ------------------------------------------------------------- exact histogram
def histogram_exact(x: jax.Array) -> jax.Array:
    """256-bin histogram with integer accumulation — exact at any count.

    The MXU ``histogram`` kernel accumulates in f32 (exact only while every
    bin stays below 2^24); entropy-coder *table construction* needs exact
    counts at any stream size, so the device twins use this scatter-add."""
    return jnp.bincount(x.astype(jnp.int32), length=256).astype(jnp.int32)


# ----------------------------------------------------------------- pack bits
def pack_bits(vals: jax.Array, offs: jax.Array, total_bytes: int):
    """Scatter pre-masked values to LSB-first packed bytes at bit offsets.

    The device twin of the host codecs' bit-matrix writer: symbol i
    contributes ``w = vals[i] << (offs[i] & 7)`` (<= 22 bits for 15-bit
    codes) to the four bytes starting at ``offs[i] >> 3``.  Every output
    *bit* has exactly one writer, so the per-byte scatter-**add** below can
    never carry — addition equals bitwise OR, and the packed bytes are
    bit-identical to the host writer's.  Values must be masked to their bit
    count already (zero-width entries carry ``vals == 0`` and add nothing).
    """
    base = offs >> 3
    w = vals.astype(jnp.uint32) << (offs & 7).astype(jnp.uint32)
    out = jnp.zeros((total_bytes + 4,), jnp.uint32)  # +4: last symbol's spill
    for t in range(4):
        out = out.at[base + t].add((w >> jnp.uint32(8 * t)) & jnp.uint32(0xFF))
    return out[:total_bytes].astype(jnp.uint8)


# ------------------------------------------------------------ huffman kernels
def huffman_map(x: jax.Array, codes: jax.Array, lens: jax.Array):
    """Per-symbol (canonical code, code length) table gathers."""
    xi = x.astype(jnp.int32)
    return jnp.take(codes.astype(jnp.uint32), xi), jnp.take(
        lens.astype(jnp.int32), xi
    )


def huffman_decode_lanes(
    buf: jax.Array, pos: jax.Array, lut_sym: jax.Array, lut_len: jax.Array, max_rem: int
):
    """Lane-parallel Huffman decode: one symbol per 32-bit window refill.

    ``buf`` is the bitstream padded >= 5 bytes past every cursor; ``pos``
    holds each lane's starting bit offset.  The host decoder drains three
    symbols per 64-bit refill; the device twin (no 64-bit lanes) refills per
    symbol — the *decoded symbols* are identical, which is all decode
    output is.  Returns (max_rem, n_lanes) u8; surplus rows of short lanes
    decode pad zeros and are trimmed by the caller.
    """
    sym = lut_sym.astype(jnp.int32)
    lnt = lut_len.astype(jnp.int32)
    n_lanes = pos.shape[0]
    out = jnp.zeros((max_rem, n_lanes), jnp.uint8)

    def step(i, carry):
        p, o = carry
        win = lane_refill(buf, p)
        low = (win & jnp.uint32(0x7FFF)).astype(jnp.int32)
        o = o.at[i].set(jnp.take(sym, low).astype(jnp.uint8))
        return p + jnp.take(lnt, low), o

    _, out = jax.lax.fori_loop(0, max_rem, step, (pos.astype(jnp.int32), out))
    return out


# ---------------------------------------------------------------- fse kernels
def fse_encode_lanes(
    lanesT: jax.Array,
    rem: jax.Array,
    nb0: jax.Array,
    thr: jax.Array,
    st0: jax.Array,
    norm: jax.Array,
    enc_flat: jax.Array,
    width: int,
    total: int,
):
    """tANS backward state walk, one vector lane per block (paper §II-A;
    state machine after the SCL FSE exemplar).

    ``lanesT`` is (max_rem, n_lanes) symbols; a lane of length r initializes
    its state at position r-1 and emits the low bits of its state for every
    earlier position.  Returns per-position (vals u32, nbits i32) planes plus
    the final per-lane states — the bit-I/O composition (offsets + packing)
    happens in ``pack_bits`` on the same device.  Arithmetic is all int32:
    states live in [0, 2*2^table_log).
    """
    max_rem, n_lanes = lanesT.shape
    nb0 = nb0.astype(jnp.int32)
    thr = thr.astype(jnp.int32)
    st0 = st0.astype(jnp.int32)
    norm = norm.astype(jnp.int32)
    enc_flat = enc_flat.astype(jnp.int32)
    rem = rem.astype(jnp.int32)
    vals0 = jnp.zeros((max_rem, n_lanes), jnp.uint32)
    nbs0 = jnp.zeros((max_rem, n_lanes), jnp.int32)

    def step(j, carry):
        state, vals, nbs = carry
        i = max_rem - 1 - j
        s = lanesT[i].astype(jnp.int32)
        emit = rem > i + 1
        X = state + total
        nb = jnp.take(nb0, s) - (X < jnp.take(thr, s)).astype(jnp.int32)
        nbe = jnp.where(emit, nb, 0)
        val = X.astype(jnp.uint32) & (
            (jnp.uint32(1) << nbe.astype(jnp.uint32)) - jnp.uint32(1)
        )
        vals = vals.at[i].set(val)
        nbs = nbs.at[i].set(nbe)
        xprime = jnp.clip((X >> nb) - jnp.take(norm, s), 0, width - 1)
        new_state = jnp.take(enc_flat, s * width + xprime)
        state = jnp.where(
            emit, new_state, jnp.where(rem == i + 1, jnp.take(st0, s), state)
        )
        return state, vals, nbs

    state, vals, nbs = jax.lax.fori_loop(
        0, max_rem, step, (jnp.zeros(n_lanes, jnp.int32), vals0, nbs0)
    )
    return vals, nbs, state


def fse_decode_lanes(
    flat: jax.Array,
    lane_base: jax.Array,
    bitlen: jax.Array,
    state0: jax.Array,
    dec_sym: jax.Array,
    dec_nb: jax.Array,
    dec_base: jax.Array,
    max_rem: int,
):
    """Lane-parallel tANS decode: forward symbol order, backward bit reads.

    ``flat`` is the concatenation of per-lane padded buffers (``lane_base``
    byte offsets); each lane's cursor starts at its bitstream length and
    walks backward.  Exhausted lanes read pad zeros and walk garbage states
    that stay in-table (base + bits < 2^table_log by construction); their
    surplus rows are trimmed by the caller.
    """
    sym = dec_sym.astype(jnp.int32)
    nbt = dec_nb.astype(jnp.int32)
    bst = dec_base.astype(jnp.int32)
    n_lanes = bitlen.shape[0]
    out = jnp.zeros((max_rem, n_lanes), jnp.uint8)

    def step(i, carry):
        state, cursor, o = carry
        o = o.at[i].set(jnp.take(sym, state).astype(jnp.uint8))
        nb = jnp.take(nbt, state)
        base = jnp.take(bst, state)
        cursor = cursor - nb
        byte0 = jnp.maximum(cursor >> 3, 0)
        win = lane_refill(flat, (lane_base + byte0) * 8 + (cursor & 7))
        bits = win & ((jnp.uint32(1) << nb.astype(jnp.uint32)) - jnp.uint32(1))
        return base + bits.astype(jnp.int32), cursor, o

    _, _, out = jax.lax.fori_loop(
        0,
        max_rem,
        step,
        (state0.astype(jnp.int32), bitlen.astype(jnp.int32), out),
    )
    return out


# --------------------------------------------------------------- lane refill
def lane_refill(buf: jax.Array, bitpos: jax.Array) -> jax.Array:
    """Entropy-lane window refill: next 32 bits at each lane's bit cursor.

    ``buf`` is the (padded) bitstream as uint8; the result is the LSB-first
    32-bit window a lane decoder consumes next.  Device twin of the numpy
    sliding-window gather in ``repro.codecs.entropy`` (32-bit because TPU
    lanes have no native 64-bit ints).
    """
    w32 = buf.astype(jnp.uint32)
    byte0 = bitpos.astype(jnp.int32) >> 3
    r = (bitpos.astype(jnp.int32) & 7).astype(jnp.uint32)
    b = [jnp.take(w32, byte0 + k) for k in range(5)]
    lo = b[0] | (b[1] << 8) | (b[2] << 16) | (b[3] << 24)
    return (lo >> r) | ((b[4] << 1) << (jnp.uint32(31) - r))
