"""Serialized compressors (paper §V-D).

A plan — codec names, params, topology, selector references — serializes to a
compact msgpack blob (<2 KB for realistic graphs, matching the paper's SAO
figure) that can be shipped around and deployed like a config file.  The wire
*frame* format (``wire.py``) is independent: frames embed resolved graphs and
never need this module to decode.
"""
from __future__ import annotations

import hashlib
from typing import Optional, Tuple

import msgpack

from .graph import KIND_CODEC, KIND_SELECTOR, Plan, PlanNode, _freeze, _thaw

SERIAL_VERSION = 1

__all__ = ["serialize_plan", "deserialize_plan", "plan_digest"]


def plan_to_dict(
    plan: Plan,
    name: str = "",
    *,
    format_version: Optional[int] = None,
    level: Optional[int] = None,
) -> dict:
    d = {
        "v": SERIAL_VERSION,
        "name": name or plan.name,
        "n_inputs": plan.n_inputs,
        "nodes": [
            {
                "k": 0 if n.kind == KIND_CODEC else 1,
                "c": n.name,
                "i": list(n.inputs),
                "o": n.n_out,
                "p": n.param_dict(),
            }
            for n in plan.nodes
        ],
    }
    # deployment knobs ride along (additive keys: old readers ignore them, old
    # blobs lack them) — without these a reloaded compressor silently reverted
    # to default format_version/level
    if format_version is not None:
        d["format_version"] = int(format_version)
    if level is not None:
        d["level"] = int(level)
    return d


def plan_from_dict(d: dict) -> Tuple[Plan, dict]:
    if d.get("v") != SERIAL_VERSION:
        raise ValueError(f"unsupported serialized-compressor version {d.get('v')}")
    nodes = tuple(
        PlanNode(
            KIND_CODEC if nd["k"] == 0 else KIND_SELECTOR,
            nd["c"],
            tuple(nd["i"]),
            nd["o"],
            _freeze(nd.get("p") or {}),
        )
        for nd in d["nodes"]
    )
    plan = Plan(d["n_inputs"], nodes, d.get("name", "")).validate()
    meta = {"name": d.get("name", "")}
    if "format_version" in d:
        meta["format_version"] = int(d["format_version"])
    if "level" in d:
        meta["level"] = int(d["level"])
    return plan, meta


def serialize_plan(
    plan: Plan,
    name: str = "",
    *,
    format_version: Optional[int] = None,
    level: Optional[int] = None,
) -> bytes:
    return msgpack.packb(
        plan_to_dict(plan, name, format_version=format_version, level=level),
        use_bin_type=True,
    )


def deserialize_plan(blob: bytes) -> Tuple[Plan, dict]:
    return plan_from_dict(msgpack.unpackb(blob, raw=False))


def plan_digest(
    plan: Plan,
    *,
    format_version: Optional[int] = None,
    level: Optional[int] = None,
) -> str:
    """Content address of a compression program: sha256 over the canonical
    serialized form (topology + params + the deployment knobs that change
    output bytes).  Two registry entries with the same digest are guaranteed
    to emit identical frames for identical input — the plan name is *not*
    hashed, so renaming a registered plan never changes its address.
    """
    d = plan_to_dict(plan, format_version=format_version, level=level)
    d["name"] = ""  # plan_to_dict falls back to plan.name; strip it here
    blob = msgpack.packb(d, use_bin_type=True)
    return hashlib.sha256(blob).hexdigest()
