"""Function graphs / selectors (paper §III-E, §V-A "Dynamism").

A selector is a named function ``fn(streams, params, ctx) -> Plan`` that picks
a sub-graph for its inputs at compression time.  Expansion happens during
encoding; the wire frame only ever records the fully *resolved* graph, so the
decoder never runs selectors — this is what keeps the decoder universal.

Selectors are registered by name so that serialized compressors (paper §V-D)
can reference them.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .codec import InPort
from .graph import Plan
from .message import Stream

__all__ = [
    "SelectorSig",
    "SelectorSpec",
    "register_selector",
    "get_selector",
    "all_selectors",
]

SelectorFn = Callable[[Sequence[Stream], dict, "CompressionCtx"], Plan]


@dataclass(frozen=True)
class SelectorSig:
    """Declared input signature of a selector.

    Selectors expand at compression time and have no static outputs — the
    signature only states which stream types the selector is *designed* for.
    Every shipped selector degrades to ``store`` when its trial menu rejects
    the input, so a mismatch is a lint warning (wasted trials), never a hard
    type error.  ``inputs`` holds one ``InPort`` per declared input; for
    variadic selectors a single port applied to every wired input.
    """

    inputs: Tuple[InPort, ...]


@dataclass(frozen=True)
class SelectorSpec:
    name: str
    fn: SelectorFn
    n_inputs: int = 1  # -1 => variadic
    doc: str = ""
    sig: Optional[SelectorSig] = None  # input signature (coverage-enforced)


_SELECTORS: Dict[str, SelectorSpec] = {}


def register_selector(spec: SelectorSpec) -> SelectorSpec:
    if spec.name in _SELECTORS:
        raise ValueError(f"duplicate selector {spec.name!r}")
    _SELECTORS[spec.name] = spec
    return spec


def get_selector(name: str) -> SelectorSpec:
    _ensure_loaded()
    try:
        return _SELECTORS[name]
    except KeyError:
        raise KeyError(
            f"unknown selector {name!r}; known: {sorted(_SELECTORS)}"
        ) from None


def all_selectors() -> Dict[str, SelectorSpec]:
    _ensure_loaded()
    return dict(_SELECTORS)


_loaded = False
_load_lock = threading.RLock()


def _ensure_loaded() -> None:
    global _loaded
    if not _loaded:
        with _load_lock:  # flag only set once the import completes (thread-safe)
            if not _loaded:
                from repro import codecs as _  # noqa: F401  (registers selectors)

                _loaded = True
