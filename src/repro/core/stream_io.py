"""File and iterator sources/sinks for the streaming sessions.

This is the layer that lets the engine compress data it never fully loads:
``iter_file_chunks`` lazily reads element-aligned chunks from a file-like
object, ``compress_file``/``decompress_file`` wire those chunks through a
:class:`~repro.core.engine.CompressorSession` /
:class:`~repro.core.engine.DecompressorSession` into/out of the container
record, with peak memory bounded by the session's in-flight window — not the
file size.  The CLI (``python -m repro``) and the serving/checkpoint paths sit
on top of these helpers.

Wire compatibility: ``compress_file(src, dst, plan, chunk_bytes=N)`` produces
byte-for-byte the same frame as ``compress(plan, serial(src_bytes),
chunk_bytes=N)`` — files small enough for a single chunk get a bare frame, not
a container, exactly like the in-memory path.
"""
from __future__ import annotations

import io
import os
import tempfile
from contextlib import contextmanager
from pathlib import Path
from typing import BinaryIO, Iterator, Optional, Union

import numpy as np

from repro.reliability.faults import crash_point, wrap_io

from . import wire
from .engine import (
    CompressionCtx,
    CompressorSession,
    DecompressorSession,
    _split_chunks,
)
from .graph import Plan
from .message import Stream, SType, serial

__all__ = [
    "iter_file_chunks",
    "iter_stream_chunks",
    "compress_file",
    "decompress_file",
]

DEFAULT_CHUNK_BYTES = 4 << 20

PathOrFile = Union[str, "os.PathLike[str]", BinaryIO]


@contextmanager
def _open(src: PathOrFile, mode: str):
    if isinstance(src, (str, os.PathLike)):
        with open(src, mode) as f:
            yield f
    else:
        yield src  # caller-owned file object: not closed here


def same_path(src: PathOrFile, dst: PathOrFile) -> bool:
    """True when two path-like arguments name the same file.

    Uses ``os.path.samefile`` (inode identity: hardlinks, symlinks) when both
    exist, falling back to resolved-path equality for a not-yet-created dst.
    File objects never compare equal — we cannot see their targets.
    """
    if not (
        isinstance(src, (str, os.PathLike)) and isinstance(dst, (str, os.PathLike))
    ):
        return False
    try:
        if os.path.exists(src) and os.path.exists(dst):
            return os.path.samefile(src, dst)
    except OSError:
        pass
    return os.path.realpath(os.fspath(src)) == os.path.realpath(os.fspath(dst))


@contextmanager
def _atomic_sink(dst: PathOrFile):
    """Open ``dst`` for writing without ever truncating the final path.

    Path destinations are written through a same-directory temp file that is
    ``os.replace``d over ``dst`` only after the writer body completes — so
    ``compress_file(f, f)`` reads the intact source while the output builds
    elsewhere (the old in-place open truncated the input before the first
    read), and a crash mid-write never leaves a partial output behind.  File
    objects pass through untouched: the caller owns their lifecycle.

    A symlink destination is resolved first, so the rename replaces the
    link's *target* (what ``open(dst, "wb")`` would have written) and the
    link itself survives.  One semantic difference from an in-place open
    remains by design: a destination hardlinked under other names gets a
    fresh inode, so the other names keep the old content — the price of
    never exposing a partially written file at the final path.
    """
    if not isinstance(dst, (str, os.PathLike)):
        yield dst
        return
    final = Path(os.path.realpath(os.fspath(dst)))
    fd, tmp_name = tempfile.mkstemp(
        dir=final.parent or Path("."), prefix=final.name + ".", suffix=".tmp"
    )
    tmp = Path(tmp_name)
    try:
        # mkstemp creates 0600: restore the mode open(dst,"wb") would have
        # given — the existing dst's mode on rewrite, else 0666 & ~umask
        try:
            mode = os.stat(final).st_mode & 0o7777
        except OSError:
            umask = os.umask(0)
            os.umask(umask)
            mode = 0o666 & ~umask
        os.chmod(fd, mode)
        # "w+b"-equivalent: mkstemp opens O_RDWR, which the unknown-length
        # container path needs for its backpatch + CRC re-read
        with os.fdopen(fd, "r+b") as f:
            yield wrap_io(f, "io.sink")
            f.flush()
            os.fsync(f.fileno())
        crash_point("sink.replace.before")
        os.replace(tmp, final)
        crash_point("sink.replace.after")
    except BaseException:
        try:
            tmp.unlink()
        except OSError:
            pass
        raise


def _input_size(f: BinaryIO) -> Optional[int]:
    """Remaining byte count, when the source can tell us (regular files).

    Non-seekable sources (sockets, pipes) may volunteer the total via a
    ``size_hint`` attribute — the service's request-body reader does, which is
    what keeps the daemon on the known-chunk-count (byte-identical) path.
    """
    hint = getattr(f, "size_hint", None)
    if hint is not None:
        return int(hint)
    try:
        if not f.seekable():
            return None
        pos = f.tell()
        end = f.seek(0, os.SEEK_END)
        f.seek(pos)
        return end - pos
    except (OSError, ValueError, AttributeError):
        # AttributeError: minimal readers (e.g. the service's BlockReader)
        # expose read() only — treat like any non-seekable source
        return None


def iter_file_chunks(
    f: BinaryIO, chunk_bytes: int = DEFAULT_CHUNK_BYTES
) -> Iterator[Stream]:
    """Lazily read a binary source as SERIAL chunk streams of ``chunk_bytes``.

    The chunk boundaries match ``engine._split_chunks`` on the whole file, so
    frames compressed from this iterator are byte-identical to the in-memory
    chunked path.  Holds one chunk at a time.
    """
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    while True:
        block = f.read(chunk_bytes)
        if not block:
            return
        yield serial(block)


def iter_stream_chunks(s: Stream, chunk_bytes: int) -> Iterator[Stream]:
    """Element-aligned chunk views over an in-memory stream (no copies)."""
    yield from _split_chunks(s, chunk_bytes)


def compress_file(
    src: PathOrFile,
    dst: PathOrFile,
    plan: Plan,
    *,
    ctx: Optional[CompressionCtx] = None,
    backend: str = "host",
    chunk_bytes: Optional[int] = DEFAULT_CHUNK_BYTES,
    n_workers: Optional[int] = None,
    window: Optional[int] = None,
    session: Optional[CompressorSession] = None,
) -> dict:
    """Compress a file without ever loading it whole -> stats dict.

    ``src``/``dst`` are paths or binary file objects.  With ``chunk_bytes``
    set (the default), the input streams through the session's bounded window;
    an input that fits one chunk becomes a bare frame.  ``chunk_bytes=0``/
    ``None`` forces the (fully in-memory) single-frame path for any size.
    Pass ``session`` to reuse a long-lived session; its plan must match.
    Returns ``{"bytes_in", "bytes_out", "chunks", "container"}``.
    """
    own_session = session is None
    if session is None:
        session = CompressorSession(
            plan,
            ctx=ctx,
            backend=backend,
            chunk_bytes=chunk_bytes,
            n_workers=n_workers,
            window=window,
        )
    elif session.plan != plan:
        raise ValueError(
            f"session plan {session.plan.name!r} does not match the requested"
            f" plan {plan.name!r}; reuse one session per plan"
        )
    try:
        # the sink must be read/writable: the unknown-length container path
        # backpatches its chunk count and re-reads the body for the CRC trailer
        with _open(src, "rb") as fin, _atomic_sink(dst) as fout:
            fin = wrap_io(fin, "io.src")
            if not chunk_bytes:
                data = fin.read()
                frame = session.compress(serial(data), chunk_bytes=0)
                fout.write(frame)
                return {
                    "bytes_in": len(data),
                    "bytes_out": len(frame),
                    "chunks": 1,
                    "container": False,
                }
            size = _input_size(fin)
            if size is not None and size <= chunk_bytes:
                data = fin.read()
                frame = session.compress(serial(data), chunk_bytes=0)
                fout.write(frame)
                return {
                    "bytes_in": len(data),
                    "bytes_out": len(frame),
                    "chunks": 1,
                    "container": False,
                }
            chunks = iter_file_chunks(fin, chunk_bytes)
            if size is None:
                # unknown length: look ahead one chunk so a short input still
                # gets a bare frame, matching the in-memory path
                first = next(chunks, None)
                if first is None:
                    first = serial(b"")
                second = next(chunks, None)
                if second is None:
                    frame = session.compress(first, chunk_bytes=0)
                    fout.write(frame)
                    return {
                        "bytes_in": first.nbytes,
                        "bytes_out": len(frame),
                        "chunks": 1,
                        "container": False,
                    }

                seen = [first.nbytes + second.nbytes]

                def _chain():
                    yield first
                    yield second
                    for ch in chunks:
                        seen[0] += ch.nbytes
                        yield ch

                before = session.stats["chunks"]
                n_out = session.compress_chunks(_chain(), fout, n_chunks=None)
                n_chunks = session.stats["chunks"] - before
                bytes_in = seen[0]
            else:
                n_chunks = -(-size // chunk_bytes)
                before = session.stats["chunks"]
                n_out = session.compress_chunks(chunks, fout, n_chunks=n_chunks)
                bytes_in = size
            return {
                "bytes_in": bytes_in,
                "bytes_out": n_out,
                "chunks": n_chunks,
                "container": True,
            }
    finally:
        if own_session:
            session.close()


def decompress_file(
    src: PathOrFile,
    dst: PathOrFile,
    *,
    n_workers: Optional[int] = None,
    window: Optional[int] = None,
    session: Optional[DecompressorSession] = None,
    salvage: bool = False,
) -> dict:
    """Universal streaming decode: any frame/container -> raw content bytes.

    Container chunks decode behind the session window and append to ``dst``
    in order — peak memory is ~window × chunk size, not the output size.  The
    written bytes are each regenerated stream's ``content_bytes()`` (for data
    compressed by ``compress_file`` / the CLI, exactly the original file).
    Returns ``{"bytes_in", "bytes_out", "chunks"}``.

    ``salvage=True`` switches to the best-effort recovery decoder
    (:meth:`DecompressorSession.decompress_salvage`): every intact chunk of a
    damaged container is written (byte-exact, in chunk order; lost chunks are
    simply absent from the output) and the returned stats carry the damage
    report under ``"salvage"``.  The default path stays fail-closed.
    """
    own_session = session is None
    if session is None:
        session = DecompressorSession(n_workers=n_workers, window=window)
    try:
        bytes_in = bytes_out = chunks = 0
        with _open(src, "rb") as fin, _atomic_sink(dst) as fout:
            fin = wrap_io(fin, "io.src")
            if salvage:
                data = fin.read()
                streams, report = session.decompress_salvage(data)
                for s in streams:
                    payload = s.content_bytes()
                    fout.write(payload)
                    bytes_out += len(payload)
                    chunks += 1
                return {
                    "bytes_in": len(data),
                    "bytes_out": bytes_out,
                    "chunks": chunks,
                    "salvage": report.to_dict(),
                }
            counted = _CountingReader(fin)
            for s in session.iter_frames(counted):
                payload = s.content_bytes()
                fout.write(payload)
                bytes_out += len(payload)
                chunks += 1
            bytes_in = counted.n
        return {"bytes_in": bytes_in, "bytes_out": bytes_out, "chunks": chunks}
    finally:
        if own_session:
            session.close()


class _CountingReader:
    def __init__(self, f: BinaryIO):
        self._f = f
        self.n = 0

    def read(self, n: int = -1) -> bytes:
        b = self._f.read(n)
        self.n += len(b)
        return b
