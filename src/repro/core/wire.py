"""The self-describing wire format (paper §I, §V).

Frame layout (all varints LEB128, little-endian payloads):

    magic   b"OZLJ"
    u8      format_version
    varint  n_graph_inputs
    varint  n_nodes
    per node:
        varint codec_id
        varint n_inputs, then n_inputs × varint input-edge-id
        varint n_outputs                  (output ids are implied sequentially)
        varint header_len, header bytes
    varint  n_stored
    per stored stream:
        varint edge_id
        u8     type tag (SType)
        varint elt width
        [STRING only] varint n_strings, n_strings × varint byte-length
        varint payload byte length, payload
    u32     crc32 of everything above

The frame embeds the *resolved* graph, which is exactly the information the
universal decoder needs — no out-of-band config, no version-locked decoder.

Multi-chunk container record (format version >= 4)
--------------------------------------------------
Chunked compression (``compress(..., chunk_bytes=N)``) stores independently
compressed chunks of one input in a *container* frame:

    magic   b"OZLC"
    u8      format_version            (>= 4)
    varint  n_chunks
    per chunk:
        varint frame byte length
        bytes  a complete single-input b"OZLJ" frame
    u32     crc32 of everything above

Each chunk is a self-describing frame in its own right (chunks may even have
been produced by different execution backends); the universal decoder decodes
every chunk and concatenates the regenerated streams.  Nesting containers is
rejected — the record is one level deep by construction.

Incremental framing (streaming sessions)
----------------------------------------
``ContainerWriter`` emits the same record one chunk at a time into any binary
sink — header first, each chunk frame as it completes, running CRC — so a
compression session never holds the whole container in memory.  With the chunk
count known up front the output is byte-identical to ``write_container``.
``iter_container_frames`` is the reading twin: it yields chunk frames from a
file-like object with memory bounded by one chunk, failing closed
(``FrameError``) on truncation, bad varints, nested containers, or a trailing
CRC mismatch.
"""
from __future__ import annotations

import struct as _struct
import zlib
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .message import Stream, SType, from_wire

MAGIC = b"OZLJ"
CONTAINER_MAGIC = b"OZLC"

__all__ = [
    "write_frame",
    "read_frame",
    "write_container",
    "read_container",
    "is_container",
    "ContainerWriter",
    "iter_container_frames",
    "read_stream_varint",
    "write_varint",
    "read_varint",
    "FrameError",
    "SalvageReport",
    "salvage_container",
    "verify_container",
]


class FrameError(ValueError):
    pass


# ------------------------------------------------------------------ varints
def write_varint(out: bytearray, value: int) -> None:
    if value < 0:
        raise ValueError("varint must be non-negative")
    while True:
        b = value & 0x7F
        value >>= 7
        if value:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def read_varint(buf: bytes, pos: int) -> Tuple[int, int]:
    result = 0
    shift = 0
    while True:
        if pos >= len(buf):
            raise FrameError("truncated varint")
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not (b & 0x80):
            return result, pos
        shift += 7
        if shift > 63:
            raise FrameError("varint overflow")


def read_stream_varint(reader) -> Tuple[int, bytes]:
    """Read one varint from a file-like object -> (value, raw bytes consumed)."""
    result = 0
    shift = 0
    raw = bytearray()
    while True:
        b = reader.read(1)
        if not b:
            raise FrameError("truncated varint")
        raw += b
        result |= (b[0] & 0x7F) << shift
        if not (b[0] & 0x80):
            return result, bytes(raw)
        shift += 7
        if shift > 63:
            raise FrameError("varint overflow")


# ------------------------------------------------------------------- frames
def write_frame(
    version: int,
    n_inputs: int,
    nodes: Sequence,  # Sequence[ResolvedNode]
    stored: Sequence[Tuple[int, Stream]],
) -> bytes:
    out = bytearray()
    out += MAGIC
    out.append(version & 0xFF)
    write_varint(out, n_inputs)
    write_varint(out, len(nodes))
    for node in nodes:
        write_varint(out, node.codec_id)
        write_varint(out, len(node.inputs))
        for e in node.inputs:
            write_varint(out, e)
        write_varint(out, node.n_out)
        write_varint(out, len(node.header))
        out += node.header
    write_varint(out, len(stored))
    for eid, s in stored:
        write_varint(out, eid)
        out.append(int(s.stype))
        write_varint(out, s.width)
        if s.stype == SType.STRING:
            lens = s.lengths if s.lengths is not None else np.zeros(0, np.uint32)
            write_varint(out, int(lens.size))
            for ln in lens.tolist():
                write_varint(out, int(ln))
        payload = s.content_bytes()
        write_varint(out, len(payload))
        out += payload
    out += _struct.pack("<I", zlib.crc32(bytes(out)) & 0xFFFFFFFF)
    return bytes(out)


def read_frame(frame: bytes):
    """Parse a frame -> (version, n_inputs, [ResolvedNode], {edge_id: Stream})."""
    from .engine import ResolvedNode  # local import to avoid cycle

    if len(frame) < 9 or frame[:4] != MAGIC:
        raise FrameError("bad magic")
    body, crc_bytes = frame[:-4], frame[-4:]
    (crc_expect,) = _struct.unpack("<I", crc_bytes)
    if (zlib.crc32(body) & 0xFFFFFFFF) != crc_expect:
        raise FrameError("checksum mismatch")
    pos = 4
    version = frame[pos]
    pos += 1
    n_inputs, pos = read_varint(frame, pos)
    n_nodes, pos = read_varint(frame, pos)
    if n_nodes > 1_000_000:
        raise FrameError("implausible node count")
    nodes: List[ResolvedNode] = []
    for _ in range(n_nodes):
        codec_id, pos = read_varint(frame, pos)
        n_in, pos = read_varint(frame, pos)
        ins = []
        for _ in range(n_in):
            e, pos = read_varint(frame, pos)
            ins.append(e)
        n_out, pos = read_varint(frame, pos)
        hlen, pos = read_varint(frame, pos)
        if pos + hlen > len(body):
            raise FrameError("truncated node header")
        header = frame[pos : pos + hlen]
        pos += hlen
        nodes.append(ResolvedNode(codec_id, tuple(ins), n_out, header))
    n_stored, pos = read_varint(frame, pos)
    stored: Dict[int, Stream] = {}
    for _ in range(n_stored):
        eid, pos = read_varint(frame, pos)
        if pos >= len(body):
            raise FrameError("truncated stream entry")
        stype = SType(frame[pos])
        pos += 1
        width, pos = read_varint(frame, pos)
        lengths = None
        if stype == SType.STRING:
            n_str, pos = read_varint(frame, pos)
            lens = np.empty(n_str, dtype=np.uint32)
            for i in range(n_str):
                ln, pos = read_varint(frame, pos)
                lens[i] = ln
            lengths = lens
        plen, pos = read_varint(frame, pos)
        if pos + plen > len(body):
            raise FrameError("truncated stream payload")
        payload = frame[pos : pos + plen]
        pos += plen
        if eid in stored:
            raise FrameError(f"edge {eid} stored twice")
        stored[eid] = from_wire(stype, width, payload, lengths)
    if pos != len(body):
        raise FrameError("trailing garbage in frame")
    return version, n_inputs, nodes, stored


# --------------------------------------------------------------- containers
def is_container(blob: bytes) -> bool:
    return bytes(blob[:4]) == CONTAINER_MAGIC


class ContainerWriter:
    """Incremental container emitter: header, then one chunk frame at a time.

    A running CRC replaces the full-container buffer, so peak memory is one
    chunk frame regardless of container size.  Two modes:

      * ``n_chunks`` given — the chunk-count varint is emitted with the header
        and the output is **byte-identical** to ``write_container`` for the
        same chunks; any binary sink works.
      * ``n_chunks=None`` — the count is unknown until :meth:`close`.  The
        sink must then be seekable *and* readable: a fixed-width (5-byte,
        LEB128-padded) count placeholder is reserved and backpatched, and the
        trailing CRC is computed by re-reading the body in blocks.  The padded
        varint decodes identically but the bytes differ from
        ``write_container`` at exactly the count field.

    Use as a context manager, or call :meth:`close` explicitly; ``close``
    verifies the promised chunk count and appends the CRC trailer.
    """

    _PAD_VARINT_LEN = 5  # 5 x 7 = 35 bits of count — far above the 1e6 cap

    def __init__(self, out, version: int, n_chunks: Optional[int] = None):
        from .versioning import CONTAINER_MIN_VERSION

        if version < CONTAINER_MIN_VERSION:
            raise ValueError(
                f"multi-chunk container requires format version"
                f" >= {CONTAINER_MIN_VERSION}, got {version}"
            )
        self._out = out
        self._expect = n_chunks
        self._written = 0
        self._closed = False
        self.bytes_written = 0
        header = bytearray()
        header += CONTAINER_MAGIC
        header.append(version & 0xFF)
        if n_chunks is not None:
            if n_chunks < 1:
                raise ValueError("container needs at least one chunk")
            write_varint(header, n_chunks)
            self._count_pos = None
        else:
            if not (out.seekable() and out.readable()):
                raise ValueError(
                    "ContainerWriter with unknown n_chunks needs a seekable,"
                    " readable sink (pass n_chunks for pure streaming)"
                )
            self._count_pos = out.tell() + len(header)
            header += self._pad_varint(0)
        self._crc = zlib.crc32(bytes(header))
        out.write(bytes(header))
        self.bytes_written += len(header)

    @classmethod
    def _pad_varint(cls, value: int) -> bytes:
        raw = bytearray()
        for _ in range(cls._PAD_VARINT_LEN - 1):
            raw.append((value & 0x7F) | 0x80)
            value >>= 7
        if value > 0x7F:
            raise ValueError("chunk count overflows the padded varint")
        raw.append(value)
        return bytes(raw)

    def write_chunk(self, frame: bytes) -> None:
        if self._closed:
            raise ValueError("ContainerWriter already closed")
        if bytes(frame[:4]) != MAGIC:
            raise ValueError("container chunks must be single frames (no nesting)")
        if self._expect is not None and self._written >= self._expect:
            raise ValueError(f"more than the promised {self._expect} chunks")
        piece = bytearray()
        write_varint(piece, len(frame))
        piece += frame
        self._crc = zlib.crc32(bytes(piece), self._crc)
        self._out.write(bytes(piece))
        self.bytes_written += len(piece)
        self._written += 1

    def close(self) -> int:
        """Finish the record (count check + CRC trailer) -> total bytes."""
        if self._closed:
            return self.bytes_written
        self._closed = True
        if self._expect is not None and self._written != self._expect:
            raise ValueError(
                f"promised {self._expect} chunks, wrote {self._written}"
            )
        if self._written == 0:
            raise ValueError("container needs at least one chunk")
        if self._count_pos is not None:
            # backpatch the count, then recompute the CRC over the final body
            end = self._out.tell()
            self._out.seek(self._count_pos)
            self._out.write(self._pad_varint(self._written))
            self._out.seek(end - self.bytes_written)
            crc = 0
            remaining = self.bytes_written
            while remaining:
                block = self._out.read(min(remaining, 1 << 20))
                if not block:
                    raise IOError("container body unreadable during CRC fixup")
                crc = zlib.crc32(block, crc)
                remaining -= len(block)
            self._crc = crc
        self._out.write(_struct.pack("<I", self._crc & 0xFFFFFFFF))
        self.bytes_written += 4
        return self.bytes_written

    def __enter__(self) -> "ContainerWriter":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        if exc_type is None:
            self.close()
        else:  # don't mask the original error with count-mismatch noise
            self._closed = True


def write_container(version: int, chunk_frames: Sequence[bytes]) -> bytes:
    """Wrap independently compressed chunk frames into one container record."""
    import io

    buf = io.BytesIO()
    with ContainerWriter(buf, version, n_chunks=len(chunk_frames)) as w:
        for frame in chunk_frames:
            w.write_chunk(frame)
    return buf.getvalue()


def iter_container_frames(
    reader,
    *,
    allow_empty: bool = False,
    salvage: bool = False,
    report: Optional["SalvageReport"] = None,
) -> Iterator[bytes]:
    """Yield chunk frames from a file-like container with bounded memory.

    Peak memory is one chunk frame (plus the fixed header), never the whole
    container.  Fails closed with :class:`FrameError` on bad magic, bad or
    truncated varints, mid-chunk EOF, nested containers, trailing garbage, and
    container-CRC mismatch.  The trailing CRC can only be verified once every
    chunk has been read, so earlier chunks are yielded before it is checked —
    each chunk frame carries its own CRC, which the universal decoder verifies
    per chunk, and the iterator still raises before completing, so a consumer
    that drains it never mistakes a corrupt container for a complete one.

    ``allow_empty=True`` accepts a structurally valid zero-chunk container
    (yielding nothing) — a record our writers refuse to produce but a foreign
    encoder may legally emit; structural readers such as ``inspect`` must
    tolerate it.  Decoding keeps the default rejection: an empty container
    regenerates no stream.

    ``salvage=True`` switches to the best-effort scanner
    (:func:`salvage_container`): instead of failing closed it yields every
    chunk frame whose own CRC verifies, skipping damaged ones, and fills
    ``report`` (a caller-supplied :class:`SalvageReport`) with the recovered
    indices and lost ranges.  The salvage path reads the whole record into
    memory — it is a recovery tool, not the default.
    """
    from .versioning import CONTAINER_MIN_VERSION

    if salvage:
        frames, rep = salvage_container(reader.read())
        if report is not None:
            report.__dict__.update(rep.__dict__)
        yield from frames
        return
    head = reader.read(5)
    if len(head) < 5 or head[:4] != CONTAINER_MAGIC:
        raise FrameError("bad container magic")
    crc = zlib.crc32(head)
    version = head[4]
    if version < CONTAINER_MIN_VERSION:
        raise FrameError(f"container frame predates format v{CONTAINER_MIN_VERSION}")
    n_chunks, raw = read_stream_varint(reader)
    crc = zlib.crc32(raw, crc)
    if n_chunks > 1_000_000:
        raise FrameError("implausible chunk count")
    if n_chunks == 0 and not allow_empty:
        raise FrameError("empty container")
    for _ in range(n_chunks):
        flen, raw = read_stream_varint(reader)
        crc = zlib.crc32(raw, crc)
        if flen > (1 << 48):
            raise FrameError("implausible chunk length")
        chunk = reader.read(flen)
        if len(chunk) != flen:
            raise FrameError("truncated container chunk")
        crc = zlib.crc32(chunk, crc)
        if chunk[:4] == CONTAINER_MAGIC:
            raise FrameError("nested container rejected")
        if chunk[:4] != MAGIC:
            raise FrameError("container chunk is not a frame")
        yield bytes(chunk)
    trailer = reader.read(4)
    if len(trailer) != 4:
        raise FrameError("truncated container trailer")
    (crc_expect,) = _struct.unpack("<I", trailer)
    if (crc & 0xFFFFFFFF) != crc_expect:
        raise FrameError("container checksum mismatch")
    if reader.read(1):
        raise FrameError("trailing garbage in container")


# ------------------------------------------------------- salvage & verify
@dataclass
class SalvageReport:
    """What a damage scan found: which chunks survived, which were lost.

    ``recovered`` / ``damaged`` hold exact chunk indices (damaged as inclusive
    ``(lo, hi)`` ranges).  When corruption destroys the *structure* (a chunk
    length varint, a truncation) the scanner resynchronizes on the next
    ``OZLJ`` magic whose structural extent carries a valid frame CRC; chunks
    recovered between two such gaps cannot be indexed exactly and are counted
    in ``recovered_unplaced`` instead.  ``trailer_ok`` is the whole-container
    CRC (None when the record is too short to have one).
    """

    n_chunks: Optional[int] = None
    recovered: List[int] = field(default_factory=list)
    recovered_unplaced: int = 0
    damaged: List[Tuple[int, Optional[int]]] = field(default_factory=list)
    trailer_ok: Optional[bool] = None
    notes: List[str] = field(default_factory=list)

    @property
    def intact(self) -> bool:
        return (
            not self.damaged
            and not self.notes
            and self.recovered_unplaced == 0
            and bool(self.trailer_ok)
            and (self.n_chunks is None or len(self.recovered) == self.n_chunks)
        )

    def damaged_ranges(self) -> str:
        def one(lo, hi):
            if hi is None:
                return f"{lo}..?"
            return str(lo) if lo == hi else f"{lo}..{hi}"

        return ", ".join(one(lo, hi) for lo, hi in self.damaged) or "none"

    def summary(self) -> str:
        total = "?" if self.n_chunks is None else str(self.n_chunks)
        parts = [
            f"chunks: {len(self.recovered)}/{total} recovered",
            f"damaged: {self.damaged_ranges()}",
        ]
        if self.recovered_unplaced:
            parts.append(f"{self.recovered_unplaced} recovered at uncertain index")
        if self.trailer_ok is not None:
            parts.append(f"container crc {'ok' if self.trailer_ok else 'BAD'}")
        for n in self.notes:
            parts.append(n)
        return "; ".join(parts)

    def to_dict(self) -> dict:
        return {
            "n_chunks": self.n_chunks,
            "recovered": list(self.recovered),
            "recovered_unplaced": self.recovered_unplaced,
            "damaged": [list(r) for r in self.damaged],
            "trailer_ok": self.trailer_ok,
            "notes": list(self.notes),
            "intact": self.intact,
        }


def _frame_extent(buf: bytes, start: int, limit: int) -> int:
    """Structural end offset of the frame starting at ``start`` (< ``limit``).

    Frames are self-delimiting — every variable-length field is preceded by
    its length — so a parse walk finds the extent without trusting any outer
    container framing.  Raises :class:`FrameError` when the walk leaves
    ``[start, limit]`` or a count is implausible.  The frame's own CRC is
    *not* checked here; callers decide what to do with the candidate.
    """
    if buf[start : start + 4] != MAGIC or start + 9 > limit:
        raise FrameError("bad magic")
    pos = start + 5  # magic + version byte

    def var(p: int) -> Tuple[int, int]:
        v, p = read_varint(buf, p)
        if p > limit:
            raise FrameError("frame walk leaves the record")
        return v, p

    _, pos = var(pos)  # n_graph_inputs
    n_nodes, pos = var(pos)
    if n_nodes > 1_000_000:
        raise FrameError("implausible node count")
    for _ in range(n_nodes):
        _, pos = var(pos)  # codec_id
        n_in, pos = var(pos)
        if n_in > 1_000_000:
            raise FrameError("implausible input count")
        for _ in range(n_in):
            _, pos = var(pos)
        _, pos = var(pos)  # n_out
        hlen, pos = var(pos)
        if pos + hlen > limit:
            raise FrameError("truncated node header")
        pos += hlen
    n_stored, pos = var(pos)
    if n_stored > 1_000_000:
        raise FrameError("implausible stored count")
    for _ in range(n_stored):
        _, pos = var(pos)  # edge id
        if pos >= limit:
            raise FrameError("truncated stream entry")
        stype = buf[pos]
        pos += 1
        _, pos = var(pos)  # width
        if stype == int(SType.STRING):
            n_str, pos = var(pos)
            if n_str > limit - pos:
                raise FrameError("implausible string count")
            for _ in range(n_str):
                _, pos = var(pos)
        plen, pos = var(pos)
        if pos + plen > limit:
            raise FrameError("truncated stream payload")
        pos += plen
    if pos + 4 > limit:
        raise FrameError("truncated frame crc")
    return pos + 4


def _frame_crc_ok(buf: bytes, start: int, end: int) -> bool:
    if end - start < 9:
        return False
    (crc_expect,) = _struct.unpack("<I", buf[end - 4 : end])
    return (zlib.crc32(buf[start : end - 4]) & 0xFFFFFFFF) == crc_expect


def salvage_container(data: bytes) -> Tuple[List[bytes], "SalvageReport"]:
    """Best-effort scan of a (possibly damaged) container record.

    Returns ``(frames, report)``: every chunk frame whose own CRC verifies,
    in physical (= chunk) order, plus a :class:`SalvageReport` saying exactly
    which chunk indices were recovered and which ranges were lost.

    Strategy: walk the normal chunk framing (length varint + frame) for as
    long as it stays believable — a chunk whose *payload* is corrupt but
    whose length prefix is intact costs exactly that one index.  When the
    structure itself breaks (bad varint, implausible length, truncation),
    resynchronize on the next ``OZLJ`` magic whose structural extent
    (:func:`_frame_extent` — frames are self-delimiting) carries a valid
    frame CRC, and resume the chunk chain after it.  Indices are assigned
    forward from 0 up to the first such gap and backward from the header's
    chunk count over the record's cleanly parsed tail; anything between two
    gaps is reported as recovered-but-unplaced.

    This is a recovery path: the whole record is held in memory (the normal
    fail-closed reader streams; use it unless the record is damaged).
    """
    report = SalvageReport()
    if len(data) < 10:
        report.notes.append(f"record too short to be a container ({len(data)} bytes)")
        return [], report
    from .versioning import CONTAINER_MIN_VERSION

    if data[:4] != CONTAINER_MAGIC:
        report.notes.append("container magic damaged")
    elif data[4] < CONTAINER_MIN_VERSION:
        report.notes.append(f"container version byte damaged ({data[4]})")
    body_end = len(data) - 4
    (crc_expect,) = _struct.unpack("<I", data[-4:])
    report.trailer_ok = (zlib.crc32(data[:body_end]) & 0xFFFFFFFF) == crc_expect
    pos = 5
    try:
        n_chunks, pos = read_varint(data, pos)
        # a chunk costs at least 10 wire bytes (1-byte length varint + the
        # 9-byte minimum frame), so a count the record cannot physically hold
        # is a damaged varint — trusting it would mis-anchor the backward
        # index assignment over the tail
        capacity = max(1, (body_end - pos) // 10)
        if 0 < n_chunks <= min(1_000_000, capacity):
            report.n_chunks = n_chunks
        else:
            report.notes.append(f"implausible chunk count {n_chunks} in header")
            pos = 5
    except FrameError:
        report.notes.append("chunk count varint unreadable")
        pos = 5
    if report.n_chunks is None:
        # header structure gone: resync straight onto the first frame magic
        first = data.find(MAGIC, pos)
        pos = first if first != -1 else body_end

    # scan -> ("ok", frame) | ("bad",) damaged chunk of known extent | ("gap",)
    items: List[Tuple[str, Optional[bytes]]] = []

    def resync(p: int) -> int:
        """Scan forward from ``p`` for a self-delimiting frame with a valid
        CRC -> offset after it (appending the recovered frame), or body_end."""
        items.append(("gap", None))
        cand = data.find(MAGIC, p)
        while cand != -1 and cand < body_end:
            try:
                end = _frame_extent(data, cand, body_end)
            except FrameError:
                end = None
            if end is not None and _frame_crc_ok(data, cand, end):
                items.append(("ok", data[cand:end]))
                return end
            cand = data.find(MAGIC, cand + 1)
        return body_end

    while pos < body_end:
        try:
            flen, npos = read_varint(data, pos)
        except FrameError:
            pos = resync(pos + 1)
            continue
        if not (9 <= flen <= body_end - npos) or data[npos : npos + 4] != MAGIC:
            pos = resync(pos + 1)
            continue
        end = npos + flen
        if _frame_crc_ok(data, npos, end):
            items.append(("ok", data[npos:end]))
        else:
            # the length prefix is believable but the frame is corrupt: only
            # trust it (and charge exactly one chunk index) when it lands on
            # another chunk boundary or the end of the record
            looks_chained = end == body_end
            if not looks_chained:
                try:
                    nxt_len, nxt_pos = read_varint(data, end)
                    looks_chained = (
                        9 <= nxt_len <= body_end - nxt_pos
                        and data[nxt_pos : nxt_pos + 4] == MAGIC
                    )
                except FrameError:
                    looks_chained = False
            if not looks_chained:
                pos = resync(pos + 1)
                continue
            items.append(("bad", None))
        pos = end
    if pos > body_end:
        items.append(("gap", None))
        report.notes.append("record truncated mid-chunk")

    # ---- index assignment: forward to the first gap, backward from the
    # header count over the clean tail, unplaced in between
    first_gap = next((i for i, (k, _) in enumerate(items) if k == "gap"), len(items))
    last_gap = max(
        (i for i, (k, _) in enumerate(items) if k == "gap"), default=-1
    )
    frames: List[bytes] = []
    damaged: List[int] = []
    idx = 0
    for kind, frame in items[:first_gap]:
        if kind == "ok":
            report.recovered.append(idx)
            frames.append(frame)
        else:
            damaged.append(idx)
        idx += 1
    fwd_end = idx  # first index not accounted for by the forward walk
    if first_gap < len(items):
        # chunks recovered between the first and last gap have no anchor on
        # either side: keep them (physical order) but report the uncertainty
        middle = items[first_gap : last_gap + 1]
        n_mid = sum(1 for k, _ in middle if k == "ok")
        frames.extend(f for k, f in middle if k == "ok")
        if n_mid:
            report.recovered_unplaced += n_mid
            report.notes.append(
                f"{n_mid} chunk(s) recovered between structural gaps"
                " (position uncertain)"
            )
        tail = items[last_gap + 1 :]
        bwd_start = None if report.n_chunks is None else report.n_chunks - len(tail)
        if pos == body_end and bwd_start is not None and bwd_start >= fwd_end:
            # the tail chain parsed cleanly through to the trailer: anchor
            # its indices backward from the header's chunk count
            j = bwd_start
            for kind, frame in tail:
                if kind == "ok":
                    report.recovered.append(j)
                    frames.append(frame)
                else:
                    damaged.append(j)
                j += 1
            if bwd_start > fwd_end:
                report.damaged.append((fwd_end, bwd_start - 1))
        else:
            frames.extend(f for k, f in tail if k == "ok")
            report.recovered_unplaced += sum(1 for k, _ in tail if k == "ok")
            hi = None if report.n_chunks is None else report.n_chunks - 1
            report.damaged.append((fwd_end, hi))
    elif report.n_chunks is not None and idx != report.n_chunks:
        report.notes.append(
            f"header promises {report.n_chunks} chunks, record holds {idx}"
        )
    # merge damaged singletons into inclusive ranges
    for i in sorted(damaged):
        if report.damaged and report.damaged[-1][1] == i - 1:
            lo, _ = report.damaged[-1]
            report.damaged[-1] = (lo, i)
        else:
            report.damaged.append((i, i))
    report.damaged.sort(key=lambda r: r[0])
    report.recovered.sort()
    return frames, report


def verify_container(reader) -> "SalvageReport":
    """Streaming integrity walk: every chunk frame's CRC plus the container
    trailer, without decoding (materializing) any payload.

    Unlike :func:`iter_container_frames` this does not fail closed on the
    first bad chunk — it keeps walking while the *structure* (length varints)
    holds, so the report lists every damaged chunk index.  A structural break
    ends the walk with a note (use :func:`salvage_container` to resync past
    it).  A bare ``OZLJ`` frame gets a single-chunk report.
    """
    from .versioning import CONTAINER_MIN_VERSION

    report = SalvageReport()
    head = reader.read(5)
    if len(head) < 5:
        report.notes.append("record too short")
        return report
    if head[:4] == MAGIC:
        frame = head + reader.read()
        report.n_chunks = 1
        if len(frame) >= 9 and _frame_crc_ok(frame, 0, len(frame)):
            report.recovered.append(0)
            report.trailer_ok = True
        else:
            report.damaged.append((0, 0))
            report.trailer_ok = False
            report.notes.append("bare frame CRC mismatch")
        return report
    if head[:4] != CONTAINER_MAGIC:
        report.notes.append("bad container magic")
        return report
    crc = zlib.crc32(head)
    if head[4] < CONTAINER_MIN_VERSION:
        report.notes.append(f"container version {head[4]} predates the record")
    try:
        n_chunks, raw = read_stream_varint(reader)
    except FrameError:
        report.notes.append("chunk count varint unreadable")
        return report
    crc = zlib.crc32(raw, crc)
    if n_chunks > 1_000_000:
        report.notes.append(f"implausible chunk count {n_chunks}")
        return report
    report.n_chunks = n_chunks
    for i in range(n_chunks):
        try:
            flen, raw = read_stream_varint(reader)
        except FrameError:
            report.notes.append(f"structure unreadable at chunk {i}")
            return report
        crc = zlib.crc32(raw, crc)
        if flen > (1 << 48):
            report.notes.append(f"implausible length for chunk {i}")
            return report
        chunk = reader.read(flen)
        if len(chunk) != flen:
            report.notes.append(f"record truncated in chunk {i}")
            report.damaged.append((i, n_chunks - 1))
            return report
        crc = zlib.crc32(chunk, crc)
        if chunk[:4] == MAGIC and _frame_crc_ok(chunk, 0, len(chunk)):
            report.recovered.append(i)
        elif report.damaged and report.damaged[-1][1] == i - 1:
            report.damaged[-1] = (report.damaged[-1][0], i)
        else:
            report.damaged.append((i, i))
    trailer = reader.read(4)
    if len(trailer) != 4:
        report.notes.append("container trailer missing")
        return report
    (crc_expect,) = _struct.unpack("<I", trailer)
    report.trailer_ok = (crc & 0xFFFFFFFF) == crc_expect
    if reader.read(1):
        report.notes.append("trailing garbage after container")
    return report


def read_container(blob: bytes):
    """Parse a container -> (version, [chunk frame bytes])."""
    from .versioning import CONTAINER_MIN_VERSION

    if len(blob) < 10 or blob[:4] != CONTAINER_MAGIC:
        raise FrameError("bad container magic")
    body, crc_bytes = blob[:-4], blob[-4:]
    (crc_expect,) = _struct.unpack("<I", crc_bytes)
    if (zlib.crc32(body) & 0xFFFFFFFF) != crc_expect:
        raise FrameError("container checksum mismatch")
    pos = 4
    version = blob[pos]
    pos += 1
    if version < CONTAINER_MIN_VERSION:
        raise FrameError(f"container frame predates format v{CONTAINER_MIN_VERSION}")
    n_chunks, pos = read_varint(blob, pos)
    if n_chunks > 1_000_000:
        raise FrameError("implausible chunk count")
    frames: List[bytes] = []
    for _ in range(n_chunks):
        flen, pos = read_varint(blob, pos)
        if pos + flen > len(body):
            raise FrameError("truncated container chunk")
        chunk = blob[pos : pos + flen]
        pos += flen
        if chunk[:4] == CONTAINER_MAGIC:
            raise FrameError("nested container rejected")
        frames.append(chunk)
    if pos != len(body):
        raise FrameError("trailing garbage in container")
    return version, frames
