"""The self-describing wire format (paper §I, §V).

Frame layout (all varints LEB128, little-endian payloads):

    magic   b"OZLJ"
    u8      format_version
    varint  n_graph_inputs
    varint  n_nodes
    per node:
        varint codec_id
        varint n_inputs, then n_inputs × varint input-edge-id
        varint n_outputs                  (output ids are implied sequentially)
        varint header_len, header bytes
    varint  n_stored
    per stored stream:
        varint edge_id
        u8     type tag (SType)
        varint elt width
        [STRING only] varint n_strings, n_strings × varint byte-length
        varint payload byte length, payload
    u32     crc32 of everything above

The frame embeds the *resolved* graph, which is exactly the information the
universal decoder needs — no out-of-band config, no version-locked decoder.

Multi-chunk container record (format version >= 4)
--------------------------------------------------
Chunked compression (``compress(..., chunk_bytes=N)``) stores independently
compressed chunks of one input in a *container* frame:

    magic   b"OZLC"
    u8      format_version            (>= 4)
    varint  n_chunks
    per chunk:
        varint frame byte length
        bytes  a complete single-input b"OZLJ" frame
    u32     crc32 of everything above

Each chunk is a self-describing frame in its own right (chunks may even have
been produced by different execution backends); the universal decoder decodes
every chunk and concatenates the regenerated streams.  Nesting containers is
rejected — the record is one level deep by construction.
"""
from __future__ import annotations

import struct as _struct
import zlib
from typing import Dict, List, Sequence, Tuple

import numpy as np

from .message import Stream, SType, from_wire

MAGIC = b"OZLJ"
CONTAINER_MAGIC = b"OZLC"

__all__ = [
    "write_frame",
    "read_frame",
    "write_container",
    "read_container",
    "is_container",
    "write_varint",
    "read_varint",
    "FrameError",
]


class FrameError(ValueError):
    pass


# ------------------------------------------------------------------ varints
def write_varint(out: bytearray, value: int) -> None:
    if value < 0:
        raise ValueError("varint must be non-negative")
    while True:
        b = value & 0x7F
        value >>= 7
        if value:
            out.append(b | 0x80)
        else:
            out.append(b)
            return


def read_varint(buf: bytes, pos: int) -> Tuple[int, int]:
    result = 0
    shift = 0
    while True:
        if pos >= len(buf):
            raise FrameError("truncated varint")
        b = buf[pos]
        pos += 1
        result |= (b & 0x7F) << shift
        if not (b & 0x80):
            return result, pos
        shift += 7
        if shift > 63:
            raise FrameError("varint overflow")


# ------------------------------------------------------------------- frames
def write_frame(
    version: int,
    n_inputs: int,
    nodes: Sequence,  # Sequence[ResolvedNode]
    stored: Sequence[Tuple[int, Stream]],
) -> bytes:
    out = bytearray()
    out += MAGIC
    out.append(version & 0xFF)
    write_varint(out, n_inputs)
    write_varint(out, len(nodes))
    for node in nodes:
        write_varint(out, node.codec_id)
        write_varint(out, len(node.inputs))
        for e in node.inputs:
            write_varint(out, e)
        write_varint(out, node.n_out)
        write_varint(out, len(node.header))
        out += node.header
    write_varint(out, len(stored))
    for eid, s in stored:
        write_varint(out, eid)
        out.append(int(s.stype))
        write_varint(out, s.width)
        if s.stype == SType.STRING:
            lens = s.lengths if s.lengths is not None else np.zeros(0, np.uint32)
            write_varint(out, int(lens.size))
            for ln in lens.tolist():
                write_varint(out, int(ln))
        payload = s.content_bytes()
        write_varint(out, len(payload))
        out += payload
    out += _struct.pack("<I", zlib.crc32(bytes(out)) & 0xFFFFFFFF)
    return bytes(out)


def read_frame(frame: bytes):
    """Parse a frame -> (version, n_inputs, [ResolvedNode], {edge_id: Stream})."""
    from .engine import ResolvedNode  # local import to avoid cycle

    if len(frame) < 9 or frame[:4] != MAGIC:
        raise FrameError("bad magic")
    body, crc_bytes = frame[:-4], frame[-4:]
    (crc_expect,) = _struct.unpack("<I", crc_bytes)
    if (zlib.crc32(body) & 0xFFFFFFFF) != crc_expect:
        raise FrameError("checksum mismatch")
    pos = 4
    version = frame[pos]
    pos += 1
    n_inputs, pos = read_varint(frame, pos)
    n_nodes, pos = read_varint(frame, pos)
    if n_nodes > 1_000_000:
        raise FrameError("implausible node count")
    nodes: List[ResolvedNode] = []
    for _ in range(n_nodes):
        codec_id, pos = read_varint(frame, pos)
        n_in, pos = read_varint(frame, pos)
        ins = []
        for _ in range(n_in):
            e, pos = read_varint(frame, pos)
            ins.append(e)
        n_out, pos = read_varint(frame, pos)
        hlen, pos = read_varint(frame, pos)
        if pos + hlen > len(body):
            raise FrameError("truncated node header")
        header = frame[pos : pos + hlen]
        pos += hlen
        nodes.append(ResolvedNode(codec_id, tuple(ins), n_out, header))
    n_stored, pos = read_varint(frame, pos)
    stored: Dict[int, Stream] = {}
    for _ in range(n_stored):
        eid, pos = read_varint(frame, pos)
        if pos >= len(body):
            raise FrameError("truncated stream entry")
        stype = SType(frame[pos])
        pos += 1
        width, pos = read_varint(frame, pos)
        lengths = None
        if stype == SType.STRING:
            n_str, pos = read_varint(frame, pos)
            lens = np.empty(n_str, dtype=np.uint32)
            for i in range(n_str):
                ln, pos = read_varint(frame, pos)
                lens[i] = ln
            lengths = lens
        plen, pos = read_varint(frame, pos)
        if pos + plen > len(body):
            raise FrameError("truncated stream payload")
        payload = frame[pos : pos + plen]
        pos += plen
        if eid in stored:
            raise FrameError(f"edge {eid} stored twice")
        stored[eid] = from_wire(stype, width, payload, lengths)
    if pos != len(body):
        raise FrameError("trailing garbage in frame")
    return version, n_inputs, nodes, stored


# --------------------------------------------------------------- containers
def is_container(blob: bytes) -> bool:
    return bytes(blob[:4]) == CONTAINER_MAGIC


def write_container(version: int, chunk_frames: Sequence[bytes]) -> bytes:
    """Wrap independently compressed chunk frames into one container record."""
    from .versioning import CONTAINER_MIN_VERSION

    if version < CONTAINER_MIN_VERSION:
        raise ValueError(
            f"multi-chunk container requires format version"
            f" >= {CONTAINER_MIN_VERSION}, got {version}"
        )
    out = bytearray()
    out += CONTAINER_MAGIC
    out.append(version & 0xFF)
    write_varint(out, len(chunk_frames))
    for frame in chunk_frames:
        if bytes(frame[:4]) != MAGIC:
            raise ValueError("container chunks must be single frames (no nesting)")
        write_varint(out, len(frame))
        out += frame
    out += _struct.pack("<I", zlib.crc32(bytes(out)) & 0xFFFFFFFF)
    return bytes(out)


def read_container(blob: bytes):
    """Parse a container -> (version, [chunk frame bytes])."""
    from .versioning import CONTAINER_MIN_VERSION

    if len(blob) < 10 or blob[:4] != CONTAINER_MAGIC:
        raise FrameError("bad container magic")
    body, crc_bytes = blob[:-4], blob[-4:]
    (crc_expect,) = _struct.unpack("<I", crc_bytes)
    if (zlib.crc32(body) & 0xFFFFFFFF) != crc_expect:
        raise FrameError("container checksum mismatch")
    pos = 4
    version = blob[pos]
    pos += 1
    if version < CONTAINER_MIN_VERSION:
        raise FrameError(f"container frame predates format v{CONTAINER_MIN_VERSION}")
    n_chunks, pos = read_varint(blob, pos)
    if n_chunks > 1_000_000:
        raise FrameError("implausible chunk count")
    frames: List[bytes] = []
    for _ in range(n_chunks):
        flen, pos = read_varint(blob, pos)
        if pos + flen > len(body):
            raise FrameError("truncated container chunk")
        chunk = blob[pos : pos + flen]
        pos += flen
        if chunk[:4] == CONTAINER_MAGIC:
            raise FrameError("nested container rejected")
        frames.append(chunk)
    if pos != len(body):
        raise FrameError("trailing garbage in container")
    return version, frames
