"""The execution engine: compress (with selector expansion) and the universal
decoder (paper §III-D).

Compression walks the plan in topological order, running codec encoders and
expanding selectors recursively.  The result is a *resolved graph* — a linear
record of (codec, input-edge-ids, n_out, header) — plus the terminal streams.
Both are serialized by :mod:`repro.core.wire` into a self-describing frame.

Decompression is purely procedural: parse the frame, then run codec decoders
in reverse topological order.  No parameters, no selectors, no user code — any
frame any graph ever produced decodes with this one function.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from . import wire
from .codec import get_codec, get_codec_by_id
from .graph import KIND_CODEC, KIND_SELECTOR, Plan
from .message import Stream, serial
from .selector import get_selector
from .versioning import (
    CURRENT_FORMAT_VERSION,
    check_compress_version,
    check_decode_version,
)

__all__ = [
    "CompressionCtx",
    "ResolvedNode",
    "compress",
    "decompress",
    "decompress_bytes",
    "Compressor",
]


@dataclass
class CompressionCtx:
    """Knobs visible to selectors during expansion."""

    format_version: int = CURRENT_FORMAT_VERSION
    level: int = 5  # 1 (fastest) .. 9 (smallest); selectors may consult this
    extras: dict = field(default_factory=dict)


@dataclass(frozen=True)
class ResolvedNode:
    codec_id: int
    inputs: Tuple[int, ...]
    n_out: int
    header: bytes


class _Execution:
    """Mutable state while compressing: resolved edge table + node list."""

    def __init__(self, ctx: CompressionCtx):
        self.ctx = ctx
        self.edges: List[Stream] = []
        self.consumed: List[bool] = []
        self.nodes: List[ResolvedNode] = []

    def new_edge(self, s: Stream) -> int:
        self.edges.append(s)
        self.consumed.append(False)
        return len(self.edges) - 1

    def consume(self, e: int) -> Stream:
        if self.consumed[e]:
            raise AssertionError(f"edge {e} consumed twice at runtime")
        self.consumed[e] = True
        return self.edges[e]

    def run_plan(self, plan: Plan, input_edge_ids: Sequence[int], depth: int = 0):
        if depth > 64:
            raise RecursionError("selector expansion too deep (cycle?)")
        if len(input_edge_ids) != plan.n_inputs:
            raise ValueError(
                f"plan {plan.name!r} wants {plan.n_inputs} inputs,"
                f" got {len(input_edge_ids)}"
            )
        emap: Dict[int, int] = {i: eid for i, eid in enumerate(input_edge_ids)}
        next_plan_edge = plan.n_inputs
        for node in plan.nodes:
            in_ids = [emap[e] for e in node.inputs]
            if node.kind == KIND_CODEC:
                spec = get_codec(node.name)
                if spec.min_version > self.ctx.format_version:
                    raise ValueError(
                        f"codec {node.name!r} requires format version"
                        f" >= {spec.min_version}, compressing at"
                        f" {self.ctx.format_version}"
                    )
                ins = [self.consume(e) for e in in_ids]
                outs, header = spec.run_encode(ins, node.param_dict())
                if len(outs) != node.n_out:
                    raise AssertionError(
                        f"codec {node.name}: declared n_out={node.n_out},"
                        f" produced {len(outs)}"
                    )
                out_ids = [self.new_edge(o) for o in outs]
                self.nodes.append(
                    ResolvedNode(spec.codec_id, tuple(in_ids), len(outs), header)
                )
                for k, oid in enumerate(out_ids):
                    emap[next_plan_edge + k] = oid
                next_plan_edge += node.n_out
            else:  # selector: expand recursively
                sel = get_selector(node.name)
                ins = [self.edges[e] for e in in_ids]  # peek, not consume
                subplan = sel.fn(ins, node.param_dict(), self.ctx).validate()
                self.run_plan(subplan, in_ids, depth + 1)


def compress(
    plan: Plan,
    inputs: Union[Stream, bytes, Sequence[Stream]],
    *,
    ctx: Optional[CompressionCtx] = None,
) -> bytes:
    """Compress ``inputs`` with ``plan`` into a self-describing frame."""
    ctx = ctx or CompressionCtx()
    check_compress_version(ctx.format_version)
    if isinstance(inputs, (bytes, bytearray, memoryview)):
        inputs = [serial(inputs)]
    elif isinstance(inputs, Stream):
        inputs = [inputs]
    inputs = [s.validate() for s in inputs]
    plan.validate()

    ex = _Execution(ctx)
    in_ids = [ex.new_edge(s) for s in inputs]
    ex.run_plan(plan, in_ids)

    stored = [
        (eid, ex.edges[eid]) for eid in range(len(ex.edges)) if not ex.consumed[eid]
    ]
    return wire.write_frame(
        ctx.format_version, len(inputs), ex.nodes, stored
    )


def decompress(frame: bytes) -> List[Stream]:
    """The universal decoder (paper §III-D): frame -> regenerated inputs."""
    version, n_inputs, nodes, stored = wire.read_frame(frame)
    check_decode_version(version)

    edges: Dict[int, Stream] = dict(stored)
    # recompute each node's output edge ids (sequential assignment)
    counter = n_inputs
    out_ids_per_node: List[Tuple[int, ...]] = []
    for node in nodes:
        out_ids_per_node.append(tuple(range(counter, counter + node.n_out)))
        counter += node.n_out

    for node, out_ids in zip(reversed(nodes), reversed(out_ids_per_node)):
        spec = get_codec_by_id(node.codec_id)
        if spec.min_version > version:
            raise ValueError(
                f"frame v{version} contains codec {spec.name!r}"
                f" (min_version {spec.min_version}) — corrupt frame?"
            )
        try:
            outs = [edges.pop(e) for e in out_ids]
        except KeyError as err:
            raise ValueError(f"corrupt frame: missing edge {err}") from None
        ins = spec.run_decode(outs, node.header)
        if len(ins) != len(node.inputs):
            raise ValueError(
                f"codec {spec.name} regenerated {len(ins)} inputs,"
                f" frame says {len(node.inputs)}"
            )
        for eid, s in zip(node.inputs, ins):
            if eid in edges:
                raise ValueError(f"corrupt frame: edge {eid} regenerated twice")
            edges[eid] = s

    try:
        return [edges[i] for i in range(n_inputs)]
    except KeyError as err:
        raise ValueError(f"corrupt frame: input edge {err} not regenerated") from None


def decompress_bytes(frame: bytes) -> bytes:
    """Single-input convenience: regenerate and return the raw content bytes."""
    (out,) = decompress(frame)
    return out.content_bytes()


class Compressor:
    """A deployable compressor: plan + default ctx + stats (public API facade)."""

    def __init__(
        self,
        plan: Plan,
        *,
        format_version: int = CURRENT_FORMAT_VERSION,
        level: int = 5,
        name: str = "",
    ):
        self.plan = plan.validate()
        self.format_version = check_compress_version(format_version)
        self.level = level
        self.name = name or plan.name

    def compress(self, inputs) -> bytes:
        ctx = CompressionCtx(self.format_version, self.level)
        return compress(self.plan, inputs, ctx=ctx)

    @staticmethod
    def decompress(frame: bytes) -> List[Stream]:
        return decompress(frame)

    def roundtrip_check(self, inputs) -> bool:
        """Encode+decode and verify bit-exactness (used by tests & the trainer)."""
        if isinstance(inputs, (bytes, bytearray)):
            inputs = [serial(inputs)]
        elif isinstance(inputs, Stream):
            inputs = [inputs]
        frame = self.compress(list(inputs))
        outs = decompress(frame)
        if len(outs) != len(inputs):
            return False
        for a, b in zip(inputs, outs):
            if a.stype != b.stype or a.width != b.width:
                return False
            if a.content_bytes() != b.content_bytes():
                return False
            if a.stype.name == "STRING" and not np.array_equal(a.lengths, b.lengths):
                return False
        return True

    def serialize(self) -> bytes:
        from .serialize import serialize_plan

        return serialize_plan(self.plan, name=self.name)

    @staticmethod
    def deserialize(blob: bytes) -> "Compressor":
        from .serialize import deserialize_plan

        plan, meta = deserialize_plan(blob)
        return Compressor(plan, name=meta.get("name", ""))
