"""The two-phase execution engine (paper §III-D, §V).

Compression is split into:

  * **resolve** — ``resolve(plan, streams, ctx) -> ResolvedPlan``: selector
    expansion.  Walks the plan in topological order, expanding selectors
    recursively, and emits a linear codec-only program.  Resolution is
    memoized on ``(plan, stream metas, level, format_version)`` so a deployed
    compressor pays for selector trials once per stream shape, not once per
    ``compress()`` call.
  * **execute** — ``execute(resolved, streams, backend=...) -> frame``: runs
    the codec encoders over concrete data.  Encoders dispatch per *backend*:
    ``host`` is the numpy codec suite; ``device`` routes numeric transform
    nodes through the jit'd Pallas wrappers in ``repro.kernels.ops`` (bit-exact
    with host) and applies a graph-rewrite pass fusing adjacent
    ``delta``+``bitpack`` nodes into the single-pass ``fused_delta_bitpack``
    kernel when its lossless precondition holds.

``compress()`` composes the two and optionally chunks large inputs
(``chunk_bytes=N``) into independently compressed pieces executed on a thread
pool (numpy/zlib/JAX release the GIL) and stored in a multi-chunk container
frame (``wire.py``, format v4+).

Sessions (streaming engine)
---------------------------
:class:`CompressorSession` / :class:`DecompressorSession` are the long-lived
form of those one-shot calls: a session owns the resolved plan, the coder-table
scratch, the backend choice, and a persistent thread pool, so a service pays
for spin-up once, not per request.  The chunked path pipelines *split →
parallel encode → in-order incremental write* behind a bounded in-flight
window (peak memory ≈ window × chunk_bytes — never the input size — when fed
from a lazy chunk source such as ``repro.core.stream_io``).  The module-level
``compress()``/``decompress()`` are thin wrappers over a throwaway session;
their wire output is unchanged, byte for byte.

Decompression is purely procedural and backend-free: parse the frame, run
codec decoders in reverse topological order.  No parameters, no selectors, no
user code — any frame any graph ever produced decodes with this one function,
including both single- and multi-chunk frames.
"""
from __future__ import annotations

import io
import os
import threading
import time
from collections import OrderedDict, deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor
from concurrent.futures import wait as _futures_wait
from dataclasses import dataclass, field
from typing import (
    BinaryIO,
    Callable,
    Dict,
    Iterable,
    Iterator,
    List,
    Optional,
    Sequence,
    Tuple,
    Union,
)

import numpy as np

from . import wire
from .codec import (
    available_backends,
    get_codec,
    get_codec_by_id,
    run_encode_via,
)
from .graph import KIND_CODEC, KIND_SELECTOR, Plan
from .message import Stream, SType, serial
from .selector import get_selector
from .versioning import (
    CONTAINER_MIN_VERSION,
    CURRENT_FORMAT_VERSION,
    check_compress_version,
    check_decode_version,
)

__all__ = [
    "CompressionCtx",
    "ExecScratch",
    "ResolvedNode",
    "ResolvedStep",
    "ResolvedPlan",
    "StreamMeta",
    "stream_meta",
    "resolve",
    "execute",
    "fuse_resolved",
    "resolve_cache_info",
    "resolve_cache_clear",
    "set_resolve_check",
    "compress",
    "decompress",
    "decompress_bytes",
    "Compressor",
    "CompressorSession",
    "DecompressorSession",
    "SessionPool",
]

FUSED_NAME = "fused_delta_bitpack"


@dataclass
class CompressionCtx:
    """Knobs visible to selectors during expansion."""

    format_version: int = CURRENT_FORMAT_VERSION
    level: int = 5  # 1 (fastest) .. 9 (smallest); selectors may consult this
    extras: dict = field(default_factory=dict)


class ExecScratch:
    """Per-``execute()`` scratch state threaded through codec invocations.

    Today it scopes the entropy coder-table cache (``repro.codecs
    .coder_cache``): one compression call — including every chunk the
    ``chunk_bytes=N`` thread pool fans out — shares a single read-only table
    namespace, so identical Huffman/FSE tables are built once, not once per
    chunk.  Chunk workers receive the *same* ``ExecScratch``; the cache it
    wraps is lock-guarded and its values immutable, which is what makes the
    sharing thread-safe.
    """

    def __init__(self, table_cache_size: int = 256):
        from repro.codecs.coder_cache import CoderCache  # lazy: no core cycle

        self.coder_cache = CoderCache(maxsize=table_cache_size)

    def activate(self):
        """Context manager making this scratch current for codec calls."""
        from repro.codecs.coder_cache import scoped

        return scoped(self.coder_cache)

    def table_cache_info(self) -> dict:
        return self.coder_cache.info()


@dataclass(frozen=True)
class ResolvedNode:
    """One executed codec as recorded on the wire (headers are per-call)."""

    codec_id: int
    inputs: Tuple[int, ...]
    n_out: int
    header: bytes


# ----------------------------------------------------------- resolved plans
@dataclass(frozen=True)
class StreamMeta:
    """The shape of a stream, for resolve-cache keying (not its contents)."""

    stype: SType
    width: int
    size_bucket: int  # floor(log2(n_elts))+1 — selector choices track scale


def stream_meta(s: Stream) -> StreamMeta:
    return StreamMeta(s.stype, s.width, int(s.n_elts).bit_length())


@dataclass(frozen=True)
class ResolvedStep:
    """One codec invocation in a resolved program.

    Edge ids are *resolved-plan* ids: inputs ``0..n_inputs-1`` are the graph
    inputs, each step's outputs take the next consecutive ids.  The execute
    phase maps these to runtime edge ids (they diverge only when a fused step
    falls back to its constituent codecs).
    """

    name: str
    codec_id: int
    inputs: Tuple[int, ...]
    n_out: int
    params: tuple = ()  # frozen dict items (graph.py _freeze format)

    def param_dict(self) -> dict:
        from .graph import _thaw

        return _thaw(self.params) if self.params else {}


@dataclass(frozen=True)
class ResolvedPlan:
    """A selector-free compression program: the cacheable resolve artifact."""

    n_inputs: int
    steps: Tuple[ResolvedStep, ...]
    format_version: int
    level: int
    name: str = ""
    fused: bool = False  # True once the delta+bitpack rewrite has run

    @property
    def n_edges(self) -> int:
        return self.n_inputs + sum(s.n_out for s in self.steps)

    def codec_names(self) -> List[str]:
        return [s.name for s in self.steps]


# ------------------------------------------------------------- resolve phase
class _Resolver:
    """Expands selectors by walking the plan over concrete streams.

    Intermediate streams are materialized with host encoders because nested
    selectors sample their *actual* inputs (trial compression).  The encoded
    bytes are discarded — only the step list survives, which is what makes
    the result reusable across calls.
    """

    def __init__(self, ctx: CompressionCtx):
        self.ctx = ctx
        self.edges: List[Stream] = []
        self.consumed: List[bool] = []
        self.steps: List[ResolvedStep] = []

    def new_edge(self, s: Stream) -> int:
        self.edges.append(s)
        self.consumed.append(False)
        return len(self.edges) - 1

    def consume(self, e: int) -> Stream:
        if self.consumed[e]:
            raise AssertionError(f"edge {e} consumed twice at resolution")
        self.consumed[e] = True
        return self.edges[e]

    def run_plan(self, plan: Plan, input_edge_ids: Sequence[int], depth: int = 0):
        if depth > 64:
            raise RecursionError("selector expansion too deep (cycle?)")
        if len(input_edge_ids) != plan.n_inputs:
            raise ValueError(
                f"plan {plan.name!r} wants {plan.n_inputs} inputs,"
                f" got {len(input_edge_ids)}"
            )
        emap: Dict[int, int] = {i: eid for i, eid in enumerate(input_edge_ids)}
        next_plan_edge = plan.n_inputs
        for node in plan.nodes:
            in_ids = [emap[e] for e in node.inputs]
            if node.kind == KIND_CODEC:
                spec = _checked_codec(node.name, self.ctx.format_version)
                ins = [self.consume(e) for e in in_ids]
                outs, _header = spec.run_encode(ins, node.param_dict())
                if len(outs) != node.n_out:
                    raise AssertionError(
                        f"codec {node.name}: declared n_out={node.n_out},"
                        f" produced {len(outs)}"
                    )
                out_ids = [self.new_edge(o) for o in outs]
                self.steps.append(
                    ResolvedStep(
                        node.name, spec.codec_id, tuple(in_ids), node.n_out, node.params
                    )
                )
                for k, oid in enumerate(out_ids):
                    emap[next_plan_edge + k] = oid
                next_plan_edge += node.n_out
            else:  # selector: expand recursively
                sel = get_selector(node.name)
                ins = [self.edges[e] for e in in_ids]  # peek, not consume
                subplan = sel.fn(ins, node.param_dict(), self.ctx).validate()
                self.run_plan(subplan, in_ids, depth + 1)


def _checked_codec(name: str, format_version: int):
    spec = get_codec(name)
    if spec.min_version > format_version:
        raise ValueError(
            f"codec {name!r} requires format version"
            f" >= {spec.min_version}, compressing at {format_version}"
        )
    return spec


def _flatten(plan: Plan, ctx: CompressionCtx) -> Tuple[ResolvedStep, ...]:
    """Selector-free plans resolve without touching any data."""
    steps = []
    for node in plan.nodes:
        spec = _checked_codec(node.name, ctx.format_version)
        steps.append(
            ResolvedStep(node.name, spec.codec_id, node.inputs, node.n_out, node.params)
        )
    return tuple(steps)


# The memo: (plan, input metas, level, format_version) -> ResolvedPlan.  LRU
# so long-running services with many stream shapes stay bounded.
_CACHE_MAX = 512
_cache: "OrderedDict[tuple, ResolvedPlan]" = OrderedDict()
_cache_lock = threading.Lock()
_cache_stats = {"hits": 0, "misses": 0}


def resolve_cache_info() -> dict:
    with _cache_lock:
        return {
            "hits": _cache_stats["hits"],
            "misses": _cache_stats["misses"],
            "size": len(_cache),
            "maxsize": _CACHE_MAX,
        }


def resolve_cache_clear() -> None:
    with _cache_lock:
        _cache.clear()
        _cache_stats["hits"] = 0
        _cache_stats["misses"] = 0


# Opt-in debug assert: type-check every plan entering resolve() against the
# concrete input types (repro.analysis).  Off by default — the static check
# belongs at the registration/training boundary, not the per-call hot path.
_RESOLVE_CHECK = os.environ.get("REPRO_RESOLVE_CHECK", "") not in ("", "0")


def set_resolve_check(enabled: bool) -> None:
    """Toggle the ``REPRO_RESOLVE_CHECK`` debug assert programmatically."""
    global _RESOLVE_CHECK
    _RESOLVE_CHECK = bool(enabled)


def _debug_check_plan(plan: Plan, metas, ctx) -> None:
    from repro.analysis import PlanTypeError, check_plan  # lazy: no cycle

    report = check_plan(
        plan,
        format_version=ctx.format_version,
        input_atoms=[(int(m.stype), int(m.width)) for m in metas],
    )
    if not report.ok:
        raise PlanTypeError(
            f"resolve check: plan {plan.name!r} is ill-typed for these"
            f" inputs: {'; '.join(str(d) for d in report.errors)}",
            report.errors,
        )


def _engine_after_fork() -> None:
    """Re-arm the module-level cache lock in a forked child.

    The service plane pre-forks session-worker processes (and forks again to
    replace a crashed one) while the parent may be resolving concurrently; a
    lock captured mid-acquire would deadlock the child's first resolve.  The
    memoized entries themselves are immutable and carry over — a worker forked
    from a warmed parent starts with a hot resolve cache.
    """
    global _cache_lock
    _cache_lock = threading.Lock()


if hasattr(os, "register_at_fork"):  # pragma: no branch - POSIX in CI
    os.register_at_fork(after_in_child=_engine_after_fork)


def _as_streams(inputs) -> List[Stream]:
    if isinstance(inputs, (bytes, bytearray, memoryview)):
        return [serial(inputs)]
    if isinstance(inputs, Stream):
        return [inputs]
    return [s for s in inputs]


def resolve(
    plan: Plan,
    inputs: Union[Stream, bytes, Sequence[Stream], Sequence[StreamMeta]],
    ctx: Optional[CompressionCtx] = None,
    *,
    use_cache: bool = True,
) -> ResolvedPlan:
    """Phase 1: expand selectors once -> a cached, inspectable ResolvedPlan.

    ``inputs`` may be concrete streams or bare :class:`StreamMeta` values;
    metas suffice only for selector-free plans (dynamic plans need real data
    to run trial compressions on).
    """
    resolved, _was_hit = _resolve_impl(plan, inputs, ctx, use_cache=use_cache)
    return resolved


def _resolve_impl(
    plan: Plan,
    inputs,
    ctx: Optional[CompressionCtx],
    *,
    use_cache: bool,
) -> Tuple[ResolvedPlan, bool]:
    """resolve() plus whether the result came from the cache (for fallback)."""
    ctx = ctx or CompressionCtx()
    check_compress_version(ctx.format_version)
    items = _as_streams(inputs) if not _all_metas(inputs) else list(inputs)
    metas_only = _all_metas(items)
    if metas_only:
        metas = tuple(items)
    else:
        items = [s.validate() for s in items]
        metas = tuple(stream_meta(s) for s in items)
    if len(metas) != plan.n_inputs:
        raise ValueError(
            f"plan {plan.name!r} wants {plan.n_inputs} inputs, got {len(metas)}"
        )

    key = (plan, metas, ctx.level, ctx.format_version)
    if use_cache:
        with _cache_lock:
            hit = _cache.get(key)
            if hit is not None:
                _cache.move_to_end(key)
                _cache_stats["hits"] += 1
                return hit, True
            _cache_stats["misses"] += 1

    plan.validate()
    if _RESOLVE_CHECK:
        _debug_check_plan(plan, metas, ctx)
    if plan.is_resolved:
        steps = _flatten(plan, ctx)
    else:
        if metas_only:
            raise ValueError(
                "resolving a plan with selectors requires concrete streams,"
                " not StreamMeta"
            )
        r = _Resolver(ctx)
        in_ids = [r.new_edge(s) for s in items]
        r.run_plan(plan, in_ids)
        steps = tuple(r.steps)
    resolved = ResolvedPlan(
        len(metas), steps, ctx.format_version, ctx.level, plan.name
    )
    if use_cache:
        with _cache_lock:
            _cache[key] = resolved
            while len(_cache) > _CACHE_MAX:
                _cache.popitem(last=False)
    return resolved, False


def _all_metas(inputs) -> bool:
    return (
        isinstance(inputs, (list, tuple))
        and len(inputs) > 0
        and all(isinstance(x, StreamMeta) for x in inputs)
    )


# ------------------------------------------------------------- fusion pass
def fuse_resolved(resolved: ResolvedPlan) -> ResolvedPlan:
    """Graph rewrite: adjacent ``delta`` -> ``bitpack`` chains become one
    ``fused_delta_bitpack`` step (single-pass kernel on the device backend).

    Static preconditions only — the data-dependent lossless precondition
    (every wrapped u32 delta fits in the packing width) is checked per call by
    the executor, which lowers the step back to its constituents when it
    fails.  Gated on the fused codec's ``min_version`` (format v4).
    """
    from repro.codecs.numeric import FUSED_BITS_CHOICES  # lazy: avoids cycle

    fused_spec = get_codec(FUSED_NAME)
    if resolved.fused or resolved.format_version < fused_spec.min_version:
        return resolved
    steps = resolved.steps
    # bitpack step index -> its delta producer index, for fusable pairs
    producer_of: Dict[int, int] = {}
    out_edge_of: Dict[int, int] = {}  # step idx -> first output edge id
    e = resolved.n_inputs
    for i, s in enumerate(steps):
        out_edge_of[i] = e
        e += s.n_out
    delta_by_out = {
        out_edge_of[i]: i
        for i, s in enumerate(steps)
        if s.name == "delta" and s.n_out == 1 and not s.params
    }
    for j, s in enumerate(steps):
        if s.name != "bitpack" or len(s.inputs) != 1:
            continue
        bits = int(s.param_dict().get("bits", 0))
        if bits and bits not in FUSED_BITS_CHOICES:
            continue  # packing width the 32-bit-word kernel can't express
        i = delta_by_out.get(s.inputs[0])
        if i is not None:
            producer_of[j] = i
    if not producer_of:
        return ResolvedPlan(
            resolved.n_inputs, steps, resolved.format_version, resolved.level,
            resolved.name, fused=True,
        )

    fused_deltas = set(producer_of.values())
    emap: Dict[int, int] = {i: i for i in range(resolved.n_inputs)}
    new_steps: List[ResolvedStep] = []
    next_new = resolved.n_inputs
    for i, s in enumerate(steps):
        old_out0 = out_edge_of[i]
        if i in fused_deltas:
            continue  # its output edge is interior to the fused pair
        if i in producer_of:
            d = steps[producer_of[i]]
            bits = int(s.param_dict().get("bits", 0))
            params = (("bits", bits),) if bits else ()
            new_steps.append(
                ResolvedStep(
                    FUSED_NAME,
                    fused_spec.codec_id,
                    tuple(emap[e] for e in d.inputs),
                    1,
                    params,
                )
            )
        else:
            new_steps.append(
                ResolvedStep(
                    s.name, s.codec_id, tuple(emap[e] for e in s.inputs),
                    s.n_out, s.params,
                )
            )
        for k in range(s.n_out):
            emap[old_out0 + k] = next_new
            next_new += 1
    return ResolvedPlan(
        resolved.n_inputs, tuple(new_steps), resolved.format_version,
        resolved.level, resolved.name, fused=True,
    )


# ------------------------------------------------------------- execute phase
class _Executor:
    """Runs a ResolvedPlan over concrete streams with backend dispatch.

    Maintains its own runtime edge numbering (``emap``: resolved edge id ->
    runtime edge id) because a fused step may lower to two wire nodes with an
    interior edge the resolved plan never saw.

    ``trace`` (optional) collects one ``(codec_name, input_bytes)`` pair per
    executed codec, in execution order — the raw material for the trainer's
    deterministic cost model (the counts are a pure function of plan + data,
    unlike wall-clock timings).
    """

    def __init__(
        self,
        resolved: ResolvedPlan,
        streams: Sequence[Stream],
        backend: str,
        trace: Optional[List[Tuple[str, int]]] = None,
    ):
        self.resolved = resolved
        self.backend = backend
        self.trace = trace
        self.edges: List[Stream] = []
        self.consumed: List[bool] = []
        self.nodes: List[ResolvedNode] = []
        self.emap: Dict[int, int] = {}
        for i, s in enumerate(streams):
            self.edges.append(s)
            self.consumed.append(False)
            self.emap[i] = i

    def _new_edge(self, s: Stream) -> int:
        self.edges.append(s)
        self.consumed.append(False)
        return len(self.edges) - 1

    def _consume(self, e: int) -> Stream:
        if self.consumed[e]:
            raise AssertionError(f"edge {e} consumed twice at runtime")
        self.consumed[e] = True
        return self.edges[e]

    def _run_codec(self, name: str, params: dict, rt_ins: List[int]) -> List[int]:
        spec = _checked_codec(name, self.resolved.format_version)
        ins = [self._consume(e) for e in rt_ins]
        if self.trace is not None:
            self.trace.append((name, sum(s.nbytes for s in ins)))
        outs, header = run_encode_via(spec, self.backend, ins, params)
        out_ids = [self._new_edge(o) for o in outs]
        self.nodes.append(ResolvedNode(spec.codec_id, tuple(rt_ins), len(outs), header))
        return out_ids

    def run(self) -> bytes:
        next_resolved_edge = self.resolved.n_inputs
        for step in self.resolved.steps:
            rt_ins = [self.emap[e] for e in step.inputs]
            if step.name == FUSED_NAME:
                out_ids = self._run_fused(step, rt_ins)
            else:
                outs_expected = step.n_out
                out_ids = self._run_codec(step.name, step.param_dict(), rt_ins)
                if len(out_ids) != outs_expected:
                    raise AssertionError(
                        f"codec {step.name}: resolved n_out={outs_expected},"
                        f" produced {len(out_ids)}"
                    )
            for k, oid in enumerate(out_ids):
                self.emap[next_resolved_edge + k] = oid
            next_resolved_edge += step.n_out
        stored = [
            (eid, self.edges[eid])
            for eid in range(len(self.edges))
            if not self.consumed[eid]
        ]
        return wire.write_frame(
            self.resolved.format_version, self.resolved.n_inputs, self.nodes, stored
        )

    def _run_fused(self, step: ResolvedStep, rt_ins: List[int]) -> List[int]:
        """Run the fused kernel when lossless, else lower to delta+bitpack.

        The encoder itself validates the lossless precondition (one pass) and
        raises a ValueError refusal when it fails — which is the lowering
        signal.  The input edge is only consumed once the attempt succeeds.
        """
        spec = _checked_codec(FUSED_NAME, self.resolved.format_version)
        params = step.param_dict()
        s = self.edges[rt_ins[0]]  # peek: do not consume before we commit
        try:
            outs, header = run_encode_via(spec, self.backend, [s], params)
        except ValueError:
            explicit = int(params.get("bits", 0))
            d_out = self._run_codec("delta", {}, rt_ins)
            return self._run_codec(
                "bitpack", {"bits": explicit} if explicit else {}, d_out
            )
        self._consume(rt_ins[0])
        if self.trace is not None:
            self.trace.append((FUSED_NAME, s.nbytes))
        out_ids = [self._new_edge(o) for o in outs]
        self.nodes.append(ResolvedNode(spec.codec_id, tuple(rt_ins), len(outs), header))
        return out_ids


def execute(
    resolved: ResolvedPlan,
    inputs: Union[Stream, bytes, Sequence[Stream]],
    *,
    backend: str = "host",
    fuse: Optional[bool] = None,
    scratch: Optional[ExecScratch] = None,
    trace: Optional[List[Tuple[str, int]]] = None,
) -> bytes:
    """Phase 2: run a resolved program over concrete streams -> wire frame.

    ``fuse`` defaults to True on the device backend (where the fused kernel
    lives); pass an explicit bool to override either way.  ``scratch`` scopes
    per-call coder-table caching; the chunked ``compress()`` path passes one
    shared scratch to every pool worker so read-only tables are built once.
    ``trace`` (a caller-owned list) collects ``(codec_name, input_bytes)`` per
    executed step — see :class:`_Executor`.
    """
    streams = [s.validate() for s in _as_streams(inputs)]
    if len(streams) != resolved.n_inputs:
        raise ValueError(
            f"resolved plan wants {resolved.n_inputs} inputs, got {len(streams)}"
        )
    if backend not in available_backends():
        raise ValueError(
            f"unknown backend {backend!r}; available: {available_backends()}"
        )
    if fuse is None:
        fuse = backend != "host"
    if fuse:
        resolved = fuse_resolved(resolved)
    if scratch is None:
        return _Executor(resolved, streams, backend, trace).run()
    with scratch.activate():
        return _Executor(resolved, streams, backend, trace).run()


# ------------------------------------------------------------------ chunking
def _split_chunks(s: Stream, chunk_bytes: int) -> List[Stream]:
    """Element-aligned split; every chunk holds at least one element.

    STRING streams pack greedily: a chunk takes whole strings while its byte
    total stays <= ``chunk_bytes`` (the first string is always taken, however
    large).  The boundaries come from one int64 cumsum over ``lengths`` plus a
    binary search per emitted chunk — O(n + chunks·log n), replacing the
    per-string Python loop.
    """
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    if s.stype == SType.STRING:
        lens = s.lengths if s.lengths is not None else np.zeros(0, np.uint32)
        if lens.size == 0:
            return [s]
        pre = np.zeros(lens.size + 1, np.int64)  # exclusive byte offsets
        np.cumsum(lens, dtype=np.int64, out=pre[1:])
        out: List[Stream] = []
        i = 0
        while i < lens.size:
            j = int(np.searchsorted(pre, pre[i] + chunk_bytes, side="right")) - 1
            j = max(j, i + 1)
            out.append(
                Stream(s.data[int(pre[i]) : int(pre[j])], SType.STRING, 1, lens[i:j])
            )
            i = j
        return out
    elt_bytes = s.width if s.stype in (SType.NUMERIC, SType.STRUCT) else 1
    per = max(1, chunk_bytes // elt_bytes)
    n = s.n_elts
    if n <= per:
        return [s]
    datum_per_elt = s.width if s.stype == SType.STRUCT else 1
    return [
        Stream(s.data[i * datum_per_elt : (i + per) * datum_per_elt], s.stype, s.width)
        for i in range(0, n, per)
    ]


def _concat_decoded(parts: List[Stream]) -> Stream:
    s0 = parts[0]
    if any(p.stype != s0.stype or p.width != s0.width for p in parts):
        raise wire.FrameError("container chunks disagree on stream type")
    if s0.stype == SType.STRING:
        data = np.concatenate([p.data for p in parts])
        lengths = np.concatenate(
            [
                p.lengths if p.lengths is not None else np.zeros(0, np.uint32)
                for p in parts
            ]
        ).astype(np.uint32)
        return Stream(data, SType.STRING, 1, lengths).validate()
    arrays = [
        p.as_unsigned().data if p.stype == SType.NUMERIC else p.data for p in parts
    ]
    return Stream(np.concatenate(arrays), s0.stype, s0.width).validate()


# ------------------------------------------------------------------ sessions
_DRAW_END = object()  # sentinel: the chunk source is exhausted


class _SessionBase:
    """Shared pool/scratch plumbing for the two session classes."""

    def __init__(
        self,
        n_workers: Optional[int],
        window: Optional[int],
        table_cache_size: int,
        pool_name: str,
        scratch: Optional[ExecScratch] = None,
        prefetch: bool = True,
    ):
        self.n_workers = n_workers
        # a caller-provided scratch lets many sessions share one coder-table
        # cache (the trainer holds hundreds of tiny per-genome sessions)
        self.scratch = scratch if scratch is not None else ExecScratch(table_cache_size)
        self._window = window
        self._pool: Optional[ThreadPoolExecutor] = None
        self._draw_pool: Optional[ThreadPoolExecutor] = None
        self._pool_lock = threading.Lock()
        self._pool_name = pool_name
        self.prefetch = prefetch
        self._stats_lock = threading.Lock()
        self.stats: Dict[str, float] = {
            "calls": 0,
            "chunks": 0,
            "bytes_in": 0,
            "bytes_out": 0,
            "max_inflight": 0,
            # double-buffer accounting: a *hit* is a source draw (split /
            # read / host->device transfer) that finished entirely in the
            # shadow of in-flight encodes; the _s counters are main-loop
            # seconds blocked on each pipeline stage
            "prefetch_hits": 0,
            "prefetch_misses": 0,
            "draw_wait_s": 0.0,
            "encode_wait_s": 0.0,
        }

    def _bump(self, **deltas: int) -> None:
        """Lock-guarded counter updates (sessions may be shared by threads)."""
        with self._stats_lock:
            for k, v in deltas.items():
                self.stats[k] += v

    def _pool_get(self) -> ThreadPoolExecutor:
        """The persistent executor, created on first chunked call."""
        with self._pool_lock:
            if self._pool is None:
                self._pool = ThreadPoolExecutor(
                    max_workers=self.n_workers or len(os.sched_getaffinity(0)),
                    thread_name_prefix=self._pool_name,
                )
            return self._pool

    def _draw_pool_get(self) -> ThreadPoolExecutor:
        """Dedicated single thread for source draws: the double buffer's host
        stage must not queue behind encodes on the shared pool, or a busy
        window would serialize exactly the work prefetch exists to hide."""
        with self._pool_lock:
            if self._draw_pool is None:
                self._draw_pool = ThreadPoolExecutor(
                    max_workers=1, thread_name_prefix=self._pool_name + "-draw"
                )
            return self._draw_pool

    @property
    def window(self) -> int:
        """Max chunks in flight: bounds peak memory at ~window × chunk size."""
        if self._window:
            return max(1, self._window)
        return 2 * (self.n_workers or len(os.sched_getaffinity(0)))

    def _window_map(
        self, fn: Callable, items: Iterable, head: Optional[list] = None
    ) -> Iterator:
        """Map ``fn`` over ``items`` on the pool, yielding results *in order*
        while keeping at most ``self.window`` tasks (and their inputs/outputs)
        alive.  ``head`` prepends already-drawn items without re-consuming the
        iterator.

        Double-buffered: with :attr:`prefetch` on, the next item is drawn
        from the source *on the pool* while encodes are in flight, so chunk
        N's encode overlaps chunk N+1's host stage (split, file read,
        host->device transfer for a lazy source).  At most one draw is in
        flight, preserving the source's single-consumer contract; the
        prefetch_hits / draw_wait_s counters in :attr:`stats` report how much
        of the host stage the overlap actually hid."""
        pool = self._pool_get()
        window = self.window
        it = iter(items)
        pending: "deque" = deque(pool.submit(fn, x) for x in (head or []))
        drawer = self._draw_pool_get() if self.prefetch else None
        draw = drawer.submit(next, it, _DRAW_END) if drawer is not None else None
        exhausted = False
        try:
            while pending or not exhausted:
                while not exhausted and len(pending) < window:
                    if draw is not None:
                        hidden = bool(pending) and draw.done()
                        t0 = time.perf_counter()
                        item = draw.result()
                        dt = time.perf_counter() - t0
                        if item is _DRAW_END:
                            exhausted = True
                            draw = None
                            break
                        pending.append(pool.submit(fn, item))
                        draw = drawer.submit(next, it, _DRAW_END)
                        with self._stats_lock:
                            key = "prefetch_hits" if hidden else "prefetch_misses"
                            self.stats[key] += 1
                            self.stats["draw_wait_s"] += dt
                    else:
                        try:
                            item = next(it)
                        except StopIteration:
                            exhausted = True
                            break
                        pending.append(pool.submit(fn, item))
                if not pending:
                    break
                with self._stats_lock:
                    if len(pending) > self.stats["max_inflight"]:
                        self.stats["max_inflight"] = len(pending)
                t0 = time.perf_counter()
                # wait on the oldest encode AND the in-flight draw: a source
                # that dies drawing chunk N+1 fails the call as soon as the
                # draw thread reports it, instead of hiding behind a full
                # window of slow encodes
                while True:
                    waiters = [pending[0]]
                    if draw is not None and not draw.done():
                        waiters.append(draw)
                    _futures_wait(waiters, return_when=FIRST_COMPLETED)
                    if (
                        draw is not None
                        and draw.done()
                        and draw.exception() is not None
                    ):
                        draw.result()  # raises the source's error promptly
                    if pending[0].done():
                        break
                result = pending.popleft().result()
                with self._stats_lock:
                    self.stats["encode_wait_s"] += time.perf_counter() - t0
                yield result
        finally:
            for fut in pending:
                fut.cancel()
            if draw is not None:
                draw.cancel()

    def close(self) -> None:
        """Release the pool.  The session object stays usable (a new pool is
        created on demand), so throwaway wrapper usage is cheap and idempotent."""
        with self._pool_lock:
            pool, self._pool = self._pool, None
            draw_pool, self._draw_pool = self._draw_pool, None
        if pool is not None:
            pool.shutdown(wait=True, cancel_futures=True)
        if draw_pool is not None:
            draw_pool.shutdown(wait=True, cancel_futures=True)

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - GC safety net
        try:
            self.close()
        except Exception:
            pass


class CompressorSession(_SessionBase):
    """A reusable, streaming compression session (one plan, many inputs).

    Owns everything a ``compress()`` call would otherwise rebuild: the
    resolve-cache handle for its plan, a coder-table :class:`ExecScratch`
    shared by every chunk it ever encodes, the backend choice, and a
    persistent thread pool.  The chunked path pipelines *split → parallel
    encode → in-order incremental write* behind a bounded in-flight window, so
    feeding it a lazy chunk iterator (``repro.core.stream_io``) compresses
    arbitrarily large inputs with peak memory ≈ ``window × chunk_bytes``.
    The window is double-buffered (``prefetch=True``): chunk N+1's host
    stage — split, file read, host->device transfer — is drawn on the pool
    while chunk N encodes, and ``stats["prefetch_hits"]`` /
    ``stats["draw_wait_s"]`` / ``stats["encode_wait_s"]`` report how much of
    it the overlap hid.  Knobs: ``window`` bounds chunks in flight,
    ``n_workers`` sizes the pool, ``prefetch`` disables the double buffer.

    Output is byte-identical to the module-level ``compress()`` with the same
    arguments — sessions change *when* work happens, never the wire format.
    Thread-safe for concurrent ``compress()`` calls (the scratch cache and
    resolve cache are lock-guarded and value-immutable).
    """

    def __init__(
        self,
        plan: Plan,
        *,
        ctx: Optional[CompressionCtx] = None,
        backend: str = "host",
        chunk_bytes: Optional[int] = None,
        n_workers: Optional[int] = None,
        window: Optional[int] = None,
        use_resolve_cache: bool = True,
        table_cache_size: int = 256,
        scratch: Optional[ExecScratch] = None,
        prefetch: bool = True,
        failover: Optional[object] = None,
    ):
        super().__init__(
            n_workers, window, table_cache_size, "ozl-enc", scratch, prefetch
        )
        self.plan = plan.validate()
        self.ctx = ctx or CompressionCtx()
        check_compress_version(self.ctx.format_version)
        if backend not in available_backends():
            raise ValueError(
                f"unknown backend {backend!r}; available: {available_backends()}"
            )
        self.backend = backend
        self.chunk_bytes = chunk_bytes
        self.use_resolve_cache = use_resolve_cache
        # duck-typed backend-health object (quarantined / record_failure /
        # record_success — e.g. repro.reliability.BackendHealth).  With one
        # installed, a chunk whose non-host backend raises is transparently
        # re-executed on host (bit-identical frames by the backend-conformance
        # guarantee) and the failure recorded; a quarantined backend is
        # skipped outright.  None (the default) keeps errors fatal.
        self.failover = failover

    # ------------------------------------------------------------ one-shot
    def compress(
        self,
        inputs: Union[Stream, bytes, Sequence[Stream]],
        *,
        chunk_bytes: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> bytes:
        """Compress to an in-memory frame (chunked -> container record).

        ``chunk_bytes`` overrides the session default; pass 0 to force an
        unchunked frame from a chunking-enabled session.
        """
        cb = self.chunk_bytes if chunk_bytes is None else chunk_bytes
        streams = [s.validate() for s in _as_streams(inputs)]
        self._bump(calls=1, bytes_in=sum(s.nbytes for s in streams))
        if cb:
            if len(streams) != 1:
                raise ValueError("chunked compression supports exactly one input")
            if self.ctx.format_version < CONTAINER_MIN_VERSION:
                raise ValueError(
                    f"chunk_bytes requires format version >= {CONTAINER_MIN_VERSION}"
                    f" (compressing at {self.ctx.format_version})"
                )
            chunks = _split_chunks(streams[0], cb)
            if len(chunks) > 1:
                buf = io.BytesIO()
                self.compress_chunks(chunks, buf, n_chunks=len(chunks), backend=backend)
                frame = buf.getvalue()
                self._bump(bytes_out=len(frame))
                return frame
        frame = self._compress_single(streams, backend or self.backend)
        self._bump(bytes_out=len(frame))
        return frame

    def _execute(
        self,
        resolved: ResolvedPlan,
        streams: List[Stream],
        backend: str,
        trace: Optional[List[Tuple[str, int]]] = None,
    ) -> bytes:
        """``execute()`` with backend-health failover to host.

        A quarantined backend is skipped before paying for the failure; an
        unquarantined one that raises is retried on host with the *same*
        resolution — only if host then succeeds is the error charged to the
        backend (a data-dependent resolve failure fails on host too and
        propagates to the caller's fresh-resolve retry, never poisoning the
        backend's health record).
        """
        fo = self.failover
        if backend != "host" and fo is not None and fo.quarantined(backend):
            backend = "host"
        try:
            out = execute(
                resolved, streams, backend=backend, scratch=self.scratch, trace=trace
            )
        except Exception as err:
            if backend == "host" or fo is None:
                raise
            if trace is not None:
                trace.clear()
            out = execute(
                resolved, streams, backend="host", scratch=self.scratch, trace=trace
            )
            fo.record_failure(backend, err)  # host succeeded: backend-specific
            return out
        if backend != "host" and fo is not None:
            fo.record_success(backend)
        return out

    def _compress_single(
        self,
        streams: List[Stream],
        backend: str,
        trace: Optional[List[Tuple[str, int]]] = None,
    ) -> bytes:
        resolved, was_hit = _resolve_impl(
            self.plan, streams, self.ctx, use_cache=self.use_resolve_cache
        )
        try:
            return self._execute(resolved, streams, backend, trace)
        except Exception:
            # A cached resolution is keyed on stream *shape*, but a selector's
            # choice can be inapplicable to new *values* of the same shape
            # (e.g. range_pack over a >57-bit range).  Re-expand for this
            # data; a failure on a fresh resolution is a genuine error.
            if not was_hit or self.plan.is_resolved:
                raise
            if trace is not None:
                trace.clear()  # the failed attempt's steps are not part of it
            fresh, _ = _resolve_impl(self.plan, streams, self.ctx, use_cache=False)
            return self._execute(fresh, streams, backend, trace)

    def compress_traced(
        self,
        inputs: Union[Stream, bytes, Sequence[Stream]],
        *,
        backend: Optional[str] = None,
    ) -> Tuple[bytes, List[Tuple[str, int]], float]:
        """Session-scoped evaluation call: one unchunked frame, instrumented.

        Returns ``(frame, trace, seconds)`` where ``trace`` is the executed
        ``(codec_name, input_bytes)`` list and ``seconds`` the wall-clock
        resolve+execute time from ``time.perf_counter`` (the clock the
        benchmarks use).  The frame is byte-identical to
        ``compress(..., chunk_bytes=0)``.  This is the trainer's candidate
        evaluation path: the trace feeds its *deterministic* cost model, the
        timing its reporting.
        """
        streams = [s.validate() for s in _as_streams(inputs)]
        trace: List[Tuple[str, int]] = []
        t0 = time.perf_counter()
        frame = self._compress_single(streams, backend or self.backend, trace)
        dt = time.perf_counter() - t0
        self._bump(
            calls=1,
            bytes_in=sum(s.nbytes for s in streams),
            bytes_out=len(frame),
        )
        return frame, trace, dt

    # ----------------------------------------------------------- streaming
    def compress_chunks(
        self,
        chunks: Iterable[Stream],
        out: BinaryIO,
        *,
        n_chunks: Optional[int] = None,
        backend: Optional[str] = None,
    ) -> int:
        """Pipelined core: parallel encode, in-order incremental container
        write.  Returns bytes written.  With ``n_chunks`` known the output is
        byte-identical to ``write_container`` over the same frames; without
        it, ``out`` must be seekable (see :class:`wire.ContainerWriter`).

        At most :attr:`window` chunks (plus their encoded frames) are held in
        memory at once — the input may be an unbounded lazy iterator.
        """
        backend = backend or self.backend
        if self.ctx.format_version < CONTAINER_MIN_VERSION:
            raise ValueError(
                f"chunked compression requires format version"
                f" >= {CONTAINER_MIN_VERSION} (at {self.ctx.format_version})"
            )
        it = iter(chunks)
        try:
            first = next(it)
        except StopIteration:
            raise ValueError("compress_chunks needs at least one chunk") from None
        # resolve once on the first chunk; workers fall back per chunk on a
        # data-dependent refusal, exactly like the one-shot chunked path
        resolved = resolve(
            self.plan, [first], self.ctx, use_cache=self.use_resolve_cache
        )

        def _one(ch: Stream) -> bytes:
            try:
                return self._execute(resolved, [ch], backend)
            except Exception:
                fresh = resolve(self.plan, [ch], self.ctx, use_cache=False)
                return self._execute(fresh, [ch], backend)

        writer = wire.ContainerWriter(out, self.ctx.format_version, n_chunks)
        for frame in self._window_map(_one, it, head=[first]):
            writer.write_chunk(frame)
            self._bump(chunks=1)
        return writer.close()

    def compress_to(
        self, inputs: Union[Stream, bytes, Sequence[Stream]], out: BinaryIO
    ) -> int:
        """Compress straight into a binary sink (single frame or container).

        Mirrors :meth:`compress` — same bytes, same errors — but never
        materializes the whole container: a multi-chunk input streams through
        :meth:`compress_chunks`.
        """
        cb = self.chunk_bytes
        streams = [s.validate() for s in _as_streams(inputs)]
        if cb:
            if len(streams) != 1:
                raise ValueError("chunked compression supports exactly one input")
            if self.ctx.format_version < CONTAINER_MIN_VERSION:
                raise ValueError(
                    f"chunk_bytes requires format version >= {CONTAINER_MIN_VERSION}"
                    f" (compressing at {self.ctx.format_version})"
                )
        chunks = _split_chunks(streams[0], cb) if cb else []
        if len(chunks) > 1:
            self._bump(calls=1, bytes_in=streams[0].nbytes)
            n = self.compress_chunks(chunks, out, n_chunks=len(chunks))
            self._bump(bytes_out=n)
            return n
        frame = self.compress(streams, chunk_bytes=0)
        out.write(frame)
        return len(frame)

    # ---------------------------------------------------------- inspection
    def resolved(self, inputs) -> ResolvedPlan:
        """Phase-1 artifact for these inputs (cached like compress())."""
        return resolve(self.plan, inputs, self.ctx, use_cache=self.use_resolve_cache)


class DecompressorSession(_SessionBase):
    """The universal decoder as a long-lived session.

    Plan-free by construction (frames are self-describing); what persists is
    the decode-side coder-table scratch and the thread pool that fans
    container chunks out.  ``decompress()`` matches the module-level function;
    :meth:`iter_frames` / :meth:`decompress_from` add the bounded-memory
    streaming path over ``wire.iter_container_frames``.
    """

    def __init__(
        self,
        *,
        n_workers: Optional[int] = None,
        window: Optional[int] = None,
        table_cache_size: int = 256,
        scratch: Optional[ExecScratch] = None,
        prefetch: bool = True,
    ):
        super().__init__(
            n_workers, window, table_cache_size, "ozl-dec", scratch, prefetch
        )

    def _one(self, frame: bytes) -> List[Stream]:
        with self.scratch.activate():
            return _decompress_single(frame)

    def decompress(self, frame: bytes) -> List[Stream]:
        """Frame or container -> regenerated input streams."""
        self._bump(calls=1, bytes_in=len(frame))
        if wire.is_container(frame):
            version, sub_frames = wire.read_container(frame)
            check_decode_version(version)
            if not sub_frames:
                raise wire.FrameError("empty container")
            if len(sub_frames) > 1:
                parts = list(self._window_map(self._one, sub_frames))
            else:
                parts = [self._one(sub_frames[0])]
            for p in parts:
                if len(p) != 1:
                    raise wire.FrameError(
                        "container chunks must be single-input frames"
                    )
            self._bump(chunks=len(parts))
            out = [_concat_decoded([p[0] for p in parts])]
        else:
            out = self._one(frame)
            self._bump(chunks=1)
        self._bump(bytes_out=sum(s.nbytes for s in out))
        return out

    # ----------------------------------------------------------- streaming
    def iter_frames(self, reader: BinaryIO) -> Iterator[Stream]:
        """Yield each container chunk's regenerated stream, in order, decoding
        up to :attr:`window` chunks concurrently with bounded memory.  A bare
        (non-container) frame yields its single stream.

        Chunk type consistency is enforced across the container; the trailing
        container CRC is verified by the underlying frame iterator before the
        final chunk is processed, and every chunk frame's own CRC is verified
        as it is decoded (fail closed, no silent partial output).
        """
        head = reader.read(4)
        rest = _Prefixed(head, reader)
        if head == wire.CONTAINER_MAGIC:
            # keep only (stype, width) of the first chunk, not its data —
            # holding the Stream would pin a whole extra chunk in memory
            ref_meta: Optional[Tuple[SType, int]] = None
            for part in self._window_map(
                self._one, wire.iter_container_frames(rest)
            ):
                if len(part) != 1:
                    raise wire.FrameError(
                        "container chunks must be single-input frames"
                    )
                (s,) = part
                if ref_meta is None:
                    ref_meta = (s.stype, s.width)
                elif (s.stype, s.width) != ref_meta:
                    raise wire.FrameError(
                        "container chunks disagree on stream type"
                    )
                self._bump(chunks=1)
                yield s
        else:
            blob = rest.read()
            for s in self.decompress(blob):
                yield s

    def decompress_from(self, reader: BinaryIO) -> List[Stream]:
        """Streaming read + decode, then concatenate (one materialized copy).

        A bare (non-container) frame decodes as-is — its streams are distinct
        graph inputs, never concatenated."""
        head = reader.read(4)
        rest = _Prefixed(head, reader)
        if head != wire.CONTAINER_MAGIC:
            return self.decompress(rest.read())
        parts = list(self.iter_frames(rest))
        if not parts:
            raise wire.FrameError("empty container")
        self.stats["calls"] += 1
        return [_concat_decoded(parts)]

    # -------------------------------------------------------------- salvage
    def decompress_salvage(
        self, src: Union[bytes, BinaryIO]
    ) -> Tuple[List[Stream], "wire.SalvageReport"]:
        """Best-effort decode of a damaged frame/container (recovery path).

        Returns ``(streams, report)``: one regenerated stream per recovered
        container chunk, in chunk order, plus the
        :class:`~repro.core.wire.SalvageReport` saying exactly which chunk
        indices survived and which ranges were lost.  Unlike
        :meth:`decompress` this never raises on damage — an unrecoverable
        record simply returns no streams and a report explaining why.  The
        whole record is held in memory; the default fail-closed readers
        remain the right tool for intact data.
        """
        data = src if isinstance(src, (bytes, bytearray)) else src.read()
        data = bytes(data)
        self._bump(calls=1, bytes_in=len(data))
        if not wire.is_container(data):
            # a bare frame has no chunk redundancy: decode or report, per its
            # own CRC — there is nothing to resynchronize on
            report = wire.SalvageReport(n_chunks=1)
            try:
                out = self._one(data)
                report.recovered.append(0)
                report.trailer_ok = True
                self._bump(chunks=1, bytes_out=sum(s.nbytes for s in out))
                return out, report
            except Exception as err:
                report.damaged.append((0, 0))
                report.trailer_ok = False
                report.notes.append(f"bare frame unrecoverable: {err}")
                return [], report
        frames, report = wire.salvage_container(data)

        def _try(frame: bytes) -> Optional[List[Stream]]:
            try:
                return self._one(frame)
            except Exception:
                return None

        parts = list(self._window_map(_try, frames)) if frames else []
        # when every recovered chunk has an exact index, frames and
        # report.recovered align (both in chunk order): a CRC-valid chunk
        # that still fails to decode moves from recovered to damaged
        aligned = (
            report.recovered_unplaced == 0
            and len(parts) == len(report.recovered)
        )
        out = []
        failed_idx: List[int] = []
        failed = 0
        for j, part in enumerate(parts):
            if part is None or len(part) != 1:
                failed += 1
                if aligned:
                    failed_idx.append(report.recovered[j])
                continue
            out.append(part[0])
        if failed:
            for i in failed_idx:
                report.recovered.remove(i)
                report.damaged.append((i, i))
            report.damaged.sort(key=lambda r: r[0])
            report.notes.append(f"{failed} recovered chunk(s) failed to decode")
        self._bump(chunks=len(out), bytes_out=sum(s.nbytes for s in out))
        return out, report


class SessionPool:
    """Thread-safe checkout pool of sessions keyed by plan digest.

    The serving layer keeps one entry per registered plan: a factory plus a
    bounded set of lazily created :class:`CompressorSession` objects.
    ``acquire(key)`` is a context manager that checks a session out for one
    request and returns it on exit; when every session of a key is in use the
    caller *blocks* until one frees — which is the service's first line of
    backpressure (the second is each session's bounded in-flight window).

    A session that dies mid-request (the context body raised) is closed and
    dropped rather than returned, so a poisoned pool member can never serve a
    later request; the next acquire simply builds a fresh one.
    """

    def __init__(self, max_per_key: int = 4):
        if max_per_key < 1:
            raise ValueError("max_per_key must be >= 1")
        self.max_per_key = max_per_key
        self._lock = threading.Condition()
        self._factories: Dict[str, Callable[[], "CompressorSession"]] = {}
        self._idle: Dict[str, List["CompressorSession"]] = {}
        self._created: Dict[str, int] = {}
        self._counters: Dict[str, Dict[str, int]] = {}

    def register(self, key: str, factory: Callable[[], "CompressorSession"]) -> None:
        """Associate ``key`` (a plan digest/id) with a session factory."""
        with self._lock:
            self._factories[key] = factory
            self._idle.setdefault(key, [])
            self._created.setdefault(key, 0)
            self._counters.setdefault(
                key, {"acquires": 0, "creates": 0, "waits": 0, "drops": 0}
            )

    def keys(self) -> List[str]:
        with self._lock:
            return sorted(self._factories)

    def acquire(self, key: str, timeout: Optional[float] = None):
        """Context manager: check a session for ``key`` out of the pool."""
        return _PoolLease(self, key, timeout)

    def _checkout(self, key: str, timeout: Optional[float]) -> "CompressorSession":
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._lock:
            if key not in self._factories:
                raise KeyError(f"no session factory registered for {key!r}")
            self._counters[key]["acquires"] += 1
            while True:
                if key not in self._factories:  # close()d while we waited
                    raise KeyError(
                        f"session pool closed while waiting for {key!r}"
                    )
                if self._idle[key]:
                    return self._idle[key].pop()
                if self._created[key] < self.max_per_key:
                    self._created[key] += 1
                    self._counters[key]["creates"] += 1
                    factory = self._factories[key]
                    break  # create outside the lock: factories may be slow
                self._counters[key]["waits"] += 1
                remaining = (
                    None if deadline is None else deadline - time.monotonic()
                )
                if remaining is not None and remaining <= 0:
                    raise TimeoutError(
                        f"no free session for {key!r} after {timeout:.1f}s"
                    )
                self._lock.wait(remaining)
        try:
            return factory()
        except BaseException:
            with self._lock:
                if key in self._created:  # close() may have raced us
                    self._created[key] -= 1
                # notify_all: one Condition spans every key, so a targeted
                # notify could wake a waiter for a different key and strand
                # the one this capacity actually frees
                self._lock.notify_all()
            raise

    def _checkin(self, key: str, session: "CompressorSession", ok: bool) -> None:
        with self._lock:
            alive = key in self._factories  # close() may have dropped the key
            if ok and alive:
                self._idle[key].append(session)
                drop = None
            else:
                if alive:
                    self._created[key] = max(0, self._created[key] - 1)
                    self._counters[key]["drops"] += 1
                drop = session
            self._lock.notify_all()  # see _checkout: one Condition, many keys
        if drop is not None:
            drop.close()

    def stats(self) -> Dict[str, dict]:
        """Per-key counters: created/idle/in_use plus acquire telemetry."""
        with self._lock:
            return {
                key: {
                    "created": self._created[key],
                    "idle": len(self._idle[key]),
                    "in_use": self._created[key] - len(self._idle[key]),
                    **self._counters[key],
                }
                for key in self._factories
            }

    def total_in_use(self) -> int:
        """Checked-out sessions across every key (0 == nothing leaked)."""
        with self._lock:
            return sum(
                self._created[key] - len(self._idle[key])
                for key in self._factories
            )

    def close(self) -> None:
        """Shut down every idle session and forget all factories.  Sessions
        currently checked out are closed by their lease on return (their key
        is gone, so ``_checkin`` drops them)."""
        with self._lock:
            idle, self._idle = self._idle, {}
            self._factories.clear()
            self._created.clear()
            self._lock.notify_all()
        for sessions in idle.values():
            for s in sessions:
                s.close()

    def __enter__(self) -> "SessionPool":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()


class _PoolLease:
    """The checkout token ``SessionPool.acquire`` hands to a ``with`` block."""

    def __init__(self, pool: SessionPool, key: str, timeout: Optional[float]):
        self._pool = pool
        self._key = key
        self._timeout = timeout
        self._session: Optional[CompressorSession] = None

    def __enter__(self) -> "CompressorSession":
        self._session = self._pool._checkout(self._key, self._timeout)
        return self._session

    def __exit__(self, exc_type, exc, tb) -> None:
        session, self._session = self._session, None
        if session is not None:
            self._pool._checkin(self._key, session, ok=exc_type is None)


class _Prefixed:
    """A tiny reader that replays already-consumed prefix bytes."""

    def __init__(self, prefix: bytes, reader: BinaryIO):
        self._prefix = prefix
        self._reader = reader

    def read(self, n: int = -1) -> bytes:
        if not self._prefix:
            return self._reader.read(n)
        if n is None or n < 0:
            out, self._prefix = self._prefix + self._reader.read(), b""
            return out
        take, self._prefix = self._prefix[:n], self._prefix[n:]
        if len(take) < n:
            take += self._reader.read(n - len(take))
        return take


# ------------------------------------------------------------------ frontend
def compress(
    plan: Plan,
    inputs: Union[Stream, bytes, Sequence[Stream]],
    *,
    ctx: Optional[CompressionCtx] = None,
    backend: str = "host",
    chunk_bytes: Optional[int] = None,
    n_workers: Optional[int] = None,
    use_resolve_cache: bool = True,
) -> bytes:
    """Compress ``inputs`` with ``plan`` into a self-describing frame.

    A thin wrapper over a throwaway :class:`CompressorSession` — long-running
    callers should hold a session instead and skip the per-call pool and
    scratch construction.

    ``chunk_bytes=N`` splits a (single) large input into independent chunks
    compressed concurrently and stored in a multi-chunk container frame
    (format v4+); the universal decoder reassembles them transparently.
    ``chunk_bytes=0``/``None`` disables chunking.

    ``use_resolve_cache=False`` forces fresh selector expansion for this
    call.  The cache is keyed on stream *shape*, so cached choices can be
    suboptimal (never wrong — a hard refusal triggers re-expansion) for new
    values of a previously seen shape; measurement code that compares
    selector choices across streams should bypass it.
    """
    with CompressorSession(
        plan,
        ctx=ctx,
        backend=backend,
        chunk_bytes=chunk_bytes,
        n_workers=n_workers,
        use_resolve_cache=use_resolve_cache,
    ) as session:
        return session.compress(inputs)


def decompress(frame: bytes, *, n_workers: Optional[int] = None) -> List[Stream]:
    """The universal decoder (paper §III-D): frame -> regenerated inputs.

    Accepts both single frames and multi-chunk containers; container chunks
    decode concurrently and concatenate back into the original stream.  A thin
    wrapper over a throwaway :class:`DecompressorSession`.
    """
    with DecompressorSession(n_workers=n_workers) as session:
        return session.decompress(frame)


def _decompress_single(frame: bytes) -> List[Stream]:
    version, n_inputs, nodes, stored = wire.read_frame(frame)
    check_decode_version(version)

    edges: Dict[int, Stream] = dict(stored)
    # recompute each node's output edge ids (sequential assignment)
    counter = n_inputs
    out_ids_per_node: List[Tuple[int, ...]] = []
    for node in nodes:
        out_ids_per_node.append(tuple(range(counter, counter + node.n_out)))
        counter += node.n_out

    for node, out_ids in zip(reversed(nodes), reversed(out_ids_per_node)):
        try:
            spec = get_codec_by_id(node.codec_id)
        except KeyError:
            # fail closed: an unknown id is a frame from a newer writer (or
            # corruption), not a programming error — name the id and the gate
            raise wire.FrameError(
                f"frame v{version} references unknown codec id"
                f" {node.codec_id} — newer writer than this decoder"
                f" (or corrupt frame); min_version gating only covers"
                f" registered codecs"
            ) from None
        if spec.min_version > version:
            raise wire.FrameError(
                f"frame v{version} contains codec {spec.name!r}"
                f" (min_version {spec.min_version}) — corrupt frame?"
            )
        try:
            outs = [edges.pop(e) for e in out_ids]
        except KeyError as err:
            raise ValueError(f"corrupt frame: missing edge {err}") from None
        ins = spec.run_decode(outs, node.header)
        if len(ins) != len(node.inputs):
            raise ValueError(
                f"codec {spec.name} regenerated {len(ins)} inputs,"
                f" frame says {len(node.inputs)}"
            )
        for eid, s in zip(node.inputs, ins):
            if eid in edges:
                raise ValueError(f"corrupt frame: edge {eid} regenerated twice")
            edges[eid] = s

    try:
        return [edges[i] for i in range(n_inputs)]
    except KeyError as err:
        raise ValueError(f"corrupt frame: input edge {err} not regenerated") from None


def decompress_bytes(frame: bytes) -> bytes:
    """Single-input convenience: regenerate and return the raw content bytes."""
    (out,) = decompress(frame)
    return out.content_bytes()


class Compressor:
    """A deployable compressor: plan + default ctx + stats (public API facade)."""

    def __init__(
        self,
        plan: Plan,
        *,
        format_version: int = CURRENT_FORMAT_VERSION,
        level: int = 5,
        name: str = "",
        backend: str = "host",
        chunk_bytes: Optional[int] = None,
    ):
        self.plan = plan.validate()
        self.format_version = check_compress_version(format_version)
        self.level = level
        self.name = name or plan.name
        self.backend = backend
        self.chunk_bytes = chunk_bytes

    def _ctx(self) -> CompressionCtx:
        return CompressionCtx(self.format_version, self.level)

    def compress(
        self,
        inputs,
        *,
        backend: Optional[str] = None,
        chunk_bytes: Optional[int] = None,
    ) -> bytes:
        """``chunk_bytes`` overrides the instance default; pass 0 to force an
        unchunked frame from a chunking-enabled compressor."""
        return compress(
            self.plan,
            inputs,
            ctx=self._ctx(),
            backend=backend or self.backend,
            chunk_bytes=self.chunk_bytes if chunk_bytes is None else chunk_bytes,
        )

    def resolve(self, inputs) -> ResolvedPlan:
        """Expose phase 1 for inspection/warm-up (cached like compress())."""
        return resolve(self.plan, inputs, self._ctx())

    def session(self, **overrides) -> "CompressorSession":
        """A long-lived streaming session with this compressor's settings.

        Keyword overrides (``backend=``, ``chunk_bytes=``, ``n_workers=``,
        ``window=``, ...) are passed through to :class:`CompressorSession`.
        """
        kw = dict(
            ctx=self._ctx(), backend=self.backend, chunk_bytes=self.chunk_bytes
        )
        kw.update(overrides)
        return CompressorSession(self.plan, **kw)

    @staticmethod
    def decompress(frame: bytes) -> List[Stream]:
        return decompress(frame)

    def roundtrip_check(self, inputs) -> bool:
        """Encode+decode and verify bit-exactness (used by tests & the trainer)."""
        if isinstance(inputs, (bytes, bytearray)):
            inputs = [serial(inputs)]
        elif isinstance(inputs, Stream):
            inputs = [inputs]
        frame = self.compress(list(inputs))
        outs = decompress(frame)
        if len(outs) != len(inputs):
            return False
        for a, b in zip(inputs, outs):
            if a.stype != b.stype or a.width != b.width:
                return False
            if a.content_bytes() != b.content_bytes():
                return False
            if a.stype.name == "STRING" and not np.array_equal(a.lengths, b.lengths):
                return False
        return True

    def serialize(self) -> bytes:
        from .serialize import serialize_plan

        return serialize_plan(
            self.plan,
            name=self.name,
            format_version=self.format_version,
            level=self.level,
        )

    @staticmethod
    def deserialize(blob: bytes) -> "Compressor":
        from .serialize import deserialize_plan

        plan, meta = deserialize_plan(blob)
        return Compressor(
            plan,
            name=meta.get("name", ""),
            format_version=meta.get("format_version", CURRENT_FORMAT_VERSION),
            level=meta.get("level", 5),
        )
