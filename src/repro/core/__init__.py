"""repro.core — the graph model of compression (paper §III).

Public API:
    Stream types ............ repro.core.message  (serial/numeric/struct/strings)
    Codec registry .......... repro.core.codec
    Graph authoring ......... repro.core.graph    (GraphBuilder, Plan, pipeline)
    Selectors ............... repro.core.selector
    Engine .................. repro.core.engine   (compress / decompress / Compressor)
    Wire format ............. repro.core.wire
    Serialized compressors .. repro.core.serialize
    Format versioning ....... repro.core.versioning
"""
from .message import Stream, SType, serial, numeric, struct, strings  # noqa: F401
from .graph import GraphBuilder, Plan, PlanNode, pipeline  # noqa: F401
from .codec import CodecSpec, register_codec, get_codec, all_codecs  # noqa: F401
from .selector import SelectorSpec, register_selector, get_selector  # noqa: F401
from .engine import (  # noqa: F401
    CompressionCtx,
    Compressor,
    compress,
    decompress,
    decompress_bytes,
)
from .versioning import CURRENT_FORMAT_VERSION, MIN_FORMAT_VERSION, VersionError  # noqa: F401
