"""repro.core — the graph model of compression (paper §III).

Public API:
    Stream types ............ repro.core.message  (serial/numeric/struct/strings)
    Codec registry .......... repro.core.codec
    Graph authoring ......... repro.core.graph    (GraphBuilder, Plan, pipeline)
    Selectors ............... repro.core.selector
    Engine .................. repro.core.engine   (compress / decompress / Compressor)
    Wire format ............. repro.core.wire
    Serialized compressors .. repro.core.serialize
    Format versioning ....... repro.core.versioning
"""
from .message import Stream, SType, serial, numeric, struct, strings  # noqa: F401
from .graph import GraphBuilder, Plan, PlanNode, pipeline  # noqa: F401
from .codec import (  # noqa: F401
    CodecSpec,
    register_codec,
    get_codec,
    all_codecs,
    register_backend_codec,
    get_backend_codec,
    available_backends,
)
from .selector import SelectorSpec, register_selector, get_selector  # noqa: F401
from .engine import (  # noqa: F401
    CompressionCtx,
    Compressor,
    CompressorSession,
    DecompressorSession,
    ExecScratch,
    ResolvedPlan,
    ResolvedStep,
    SessionPool,
    StreamMeta,
    compress,
    decompress,
    decompress_bytes,
    execute,
    fuse_resolved,
    resolve,
    resolve_cache_clear,
    resolve_cache_info,
    set_resolve_check,
    stream_meta,
)
from .versioning import (  # noqa: F401
    CONTAINER_MIN_VERSION,
    CURRENT_FORMAT_VERSION,
    MIN_FORMAT_VERSION,
    VersionError,
)
