"""Compression graphs (paper §III-C, §III-E).

A :class:`Plan` is the static description of a compressor: a DAG whose nodes
are codecs (or *selectors* — function graphs that expand at compression time)
and whose edges are streams.  Edge ids are assigned topologically:

  * ids ``0 .. n_inputs-1`` are the graph inputs,
  * each node's outputs take the next consecutive ids.

Every edge has exactly one producer and at most one consumer (fan-out is an
explicit ``dup`` codec, keeping decode purely procedural).  Edges nobody
consumes are *terminal*: their streams are what the wire format stores.

A Plan is the *configuration* of a compressor; turning it into an executable,
selector-free program is the engine's resolve phase (``repro.core.engine``),
which memoizes on the Plan value — Plans are frozen/hashable for exactly that
reason.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from .codec import get_codec

__all__ = ["PlanNode", "Plan", "GraphBuilder", "pipeline"]

KIND_CODEC = "codec"
KIND_SELECTOR = "selector"


def _freeze(obj):
    """Recursively freeze params into hashable/JSON-able structures."""
    if isinstance(obj, dict):
        return tuple(sorted((k, _freeze(v)) for k, v in obj.items()))
    if isinstance(obj, (list, tuple)):
        return tuple(_freeze(v) for v in obj)
    return obj


def _thaw(obj):
    if isinstance(obj, tuple) and all(
        isinstance(kv, tuple) and len(kv) == 2 and isinstance(kv[0], str) for kv in obj
    ):
        return {k: _thaw(v) for k, v in obj}
    if isinstance(obj, tuple):
        return [_thaw(v) for v in obj]
    return obj


@dataclass(frozen=True)
class PlanNode:
    kind: str  # KIND_CODEC | KIND_SELECTOR
    name: str
    inputs: Tuple[int, ...]
    n_out: int
    params: tuple = ()  # frozen dict items

    def param_dict(self) -> dict:
        return _thaw(self.params) if self.params else {}


@dataclass(frozen=True)
class Plan:
    """A (possibly dynamic) compression graph."""

    n_inputs: int
    nodes: Tuple[PlanNode, ...]
    name: str = ""

    # ------------------------------------------------------------ validation
    def validate(self) -> "Plan":
        next_edge = self.n_inputs
        consumed: Dict[int, int] = {}
        for i, node in enumerate(self.nodes):
            if node.kind not in (KIND_CODEC, KIND_SELECTOR):
                raise ValueError(f"node {i}: bad kind {node.kind!r}")
            for e in node.inputs:
                if not (0 <= e < next_edge):
                    raise ValueError(f"node {i} ({node.name}): input edge {e} undefined")
                if e in consumed:
                    raise ValueError(
                        f"edge {e} consumed twice (nodes {consumed[e]} and {i});"
                        " use the 'dup' codec for fan-out"
                    )
                consumed[e] = i
            if node.kind == KIND_SELECTOR and node.n_out != 0:
                raise ValueError(f"selector node {i} must have n_out == 0")
            if node.kind == KIND_CODEC:
                spec = get_codec(node.name)
                if spec.n_inputs >= 0 and len(node.inputs) != spec.n_inputs:
                    raise ValueError(
                        f"node {i} ({node.name}): wants {spec.n_inputs} inputs,"
                        f" wired {len(node.inputs)}"
                    )
                if spec.n_outputs >= 0 and node.n_out != spec.n_outputs:
                    raise ValueError(
                        f"node {i} ({node.name}): spec has {spec.n_outputs} outputs,"
                        f" declared {node.n_out}"
                    )
            next_edge += node.n_out
        return self

    @property
    def is_resolved(self) -> bool:
        return all(n.kind == KIND_CODEC for n in self.nodes)

    def require_resolved(self) -> "Plan":
        """Raise unless the plan is selector-free (executable without data)."""
        for i, n in enumerate(self.nodes):
            if n.kind == KIND_SELECTOR:
                raise ValueError(
                    f"node {i} ({n.name!r}) is a selector; resolve the plan first"
                )
        return self

    @property
    def n_edges(self) -> int:
        return self.n_inputs + sum(n.n_out for n in self.nodes)

    def terminal_edges(self) -> List[int]:
        consumed = {e for n in self.nodes for e in n.inputs}
        return [e for e in range(self.n_edges) if e not in consumed]

    def codec_names(self) -> List[str]:
        return [n.name for n in self.nodes if n.kind == KIND_CODEC]


class GraphBuilder:
    """Imperative builder for :class:`Plan` (the public authoring API).

    >>> g = GraphBuilder(n_inputs=1)
    >>> tok, idx = g.add("tokenize", g.input(0))
    >>> g.add("huffman", idx)
    >>> plan = g.build("my_compressor")
    """

    def __init__(self, n_inputs: int = 1):
        self.n_inputs = n_inputs
        self._nodes: List[PlanNode] = []
        self._next_edge = n_inputs

    def input(self, i: int = 0) -> int:
        if not (0 <= i < self.n_inputs):
            raise IndexError(f"graph has {self.n_inputs} inputs")
        return i

    def add(self, codec: str, *inputs: int, n_out: Optional[int] = None, **params):
        spec = get_codec(codec)
        if n_out is None:
            if spec.n_outputs < 0:
                raise ValueError(
                    f"codec {codec} has variadic outputs; pass n_out= explicitly"
                )
            n_out = spec.n_outputs
        node = PlanNode(KIND_CODEC, codec, tuple(inputs), n_out, _freeze(params))
        self._nodes.append(node)
        outs = list(range(self._next_edge, self._next_edge + n_out))
        self._next_edge += n_out
        if len(outs) == 1:
            return outs[0]
        return outs

    def select(self, selector: str, *inputs: int, **params) -> None:
        """Attach a function graph (expands at compression time; paper §III-E)."""
        node = PlanNode(KIND_SELECTOR, selector, tuple(inputs), 0, _freeze(params))
        self._nodes.append(node)

    def build(self, name: str = "") -> Plan:
        return Plan(self.n_inputs, tuple(self._nodes), name).validate()


def pipeline(*codecs, name: str = "") -> Plan:
    """Linear chain convenience: each entry is a codec name or (name, params).

    Multi-output codecs in the middle route output 0 onward; other outputs
    terminate.  The last stage's outputs all terminate.
    """
    g = GraphBuilder(1)
    cur = g.input(0)
    for item in codecs:
        cname, params = (item, {}) if isinstance(item, str) else (item[0], dict(item[1]))
        spec = get_codec(cname)
        n_out = params.pop("n_out", None)
        outs = g.add(cname, cur, n_out=n_out, **params)
        cur = outs if isinstance(outs, int) else outs[0]
    return g.build(name or "+".join(c if isinstance(c, str) else c[0] for c in codecs))
