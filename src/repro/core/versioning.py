"""Format versioning (paper §V-C).

A library release supports a *range* of wire format versions.  At compression
time the caller selects a version all its decoders support; the engine then
refuses any codec whose ``min_version`` is newer (codec-by-codec wire
evolution).  Frames carry their version; the universal decoder validates it
against the supported range.
"""
from __future__ import annotations

MIN_FORMAT_VERSION = 1
# v1: core transforms (store/delta/zigzag/transpose/bitpack/rle/constant/split)
# v2: tokenize/string codecs, huffman, fse, lz, parsers
# v3: float_split family, lane-parallel entropy variants, zlib backend
# v4: multi-chunk container frames (wire.py OZLC record) + fused_delta_bitpack
CURRENT_FORMAT_VERSION = 4

# First format version whose decoders understand the multi-chunk container
# record; compress(chunk_bytes=...) refuses to emit one at older versions.
CONTAINER_MIN_VERSION = 4


class VersionError(ValueError):
    pass


def check_compress_version(version: int) -> int:
    if not (MIN_FORMAT_VERSION <= version <= CURRENT_FORMAT_VERSION):
        raise VersionError(
            f"format version {version} outside supported"
            f" [{MIN_FORMAT_VERSION}, {CURRENT_FORMAT_VERSION}]"
        )
    return version


def check_decode_version(version: int) -> int:
    if not (MIN_FORMAT_VERSION <= version <= CURRENT_FORMAT_VERSION):
        raise VersionError(
            f"frame format version {version} not supported by this library"
            f" (supports [{MIN_FORMAT_VERSION}, {CURRENT_FORMAT_VERSION}])"
        )
    return version
