"""Codec registry — the node vocabulary of the graph model (paper §III-B, §V-A).

A codec is a reversible pair ``(encode, decode)`` over tuples of streams.  The
contract that makes the *universal decoder* possible (paper §III-D):

  * ``encode(streams, params) -> (out_streams, header)`` — ``params`` may shape
    the encoding arbitrarily.
  * ``decode(out_streams, header) -> streams`` — **parameter-free**: everything
    decode needs must be in the (per-node, wire-stored) ``header`` bytes.

Codec ids are wire-stable; ``min_version`` implements the paper's codec-by-codec
format-version gating (§V-C).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .message import Stream

__all__ = ["CodecSpec", "register_codec", "get_codec", "get_codec_by_id", "all_codecs"]

EncodeFn = Callable[..., Tuple[List[Stream], bytes]]
DecodeFn = Callable[[Sequence[Stream], bytes], List[Stream]]


@dataclass(frozen=True)
class CodecSpec:
    name: str
    codec_id: int  # wire-stable; never reuse
    encode: EncodeFn
    decode: DecodeFn
    n_inputs: int = 1  # -1 => variadic
    n_outputs: int = 1  # -1 => variadic (actual count recorded per node on wire)
    min_version: int = 1  # first format version that understands this codec
    doc: str = ""

    def run_encode(self, streams: Sequence[Stream], params: Optional[dict] = None):
        params = dict(params or {})
        if self.n_inputs >= 0 and len(streams) != self.n_inputs:
            raise ValueError(
                f"codec {self.name}: expected {self.n_inputs} inputs, got {len(streams)}"
            )
        outs, header = self.encode(list(streams), params)
        if self.n_outputs >= 0 and len(outs) != self.n_outputs:
            raise AssertionError(
                f"codec {self.name}: produced {len(outs)} outputs, spec says {self.n_outputs}"
            )
        if not isinstance(header, (bytes, bytearray)):
            raise AssertionError(f"codec {self.name}: header must be bytes")
        return [o.validate() for o in outs], bytes(header)

    def run_decode(self, out_streams: Sequence[Stream], header: bytes):
        ins = self.decode(list(out_streams), header)
        return [s.validate() for s in ins]


_BY_NAME: Dict[str, CodecSpec] = {}
_BY_ID: Dict[int, CodecSpec] = {}


def register_codec(spec: CodecSpec) -> CodecSpec:
    if spec.name in _BY_NAME:
        raise ValueError(f"duplicate codec name {spec.name!r}")
    if spec.codec_id in _BY_ID:
        raise ValueError(
            f"duplicate codec id {spec.codec_id} ({spec.name!r} vs"
            f" {_BY_ID[spec.codec_id].name!r})"
        )
    _BY_NAME[spec.name] = spec
    _BY_ID[spec.codec_id] = spec
    return spec


def get_codec(name: str) -> CodecSpec:
    _ensure_standard_library()
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; known: {sorted(_BY_NAME)}") from None


def get_codec_by_id(codec_id: int) -> CodecSpec:
    _ensure_standard_library()
    try:
        return _BY_ID[codec_id]
    except KeyError:
        raise KeyError(f"unknown codec id {codec_id}") from None


def all_codecs() -> Dict[str, CodecSpec]:
    _ensure_standard_library()
    return dict(_BY_NAME)


_loaded = False


def _ensure_standard_library() -> None:
    """Lazily import the standard codec suite so `core` has no import cycle."""
    global _loaded
    if not _loaded:
        _loaded = True
        from repro import codecs as _  # noqa: F401  (registers on import)
