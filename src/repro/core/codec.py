"""Codec registry — the node vocabulary of the graph model (paper §III-B, §V-A).

A codec is a reversible pair ``(encode, decode)`` over tuples of streams.  The
contract that makes the *universal decoder* possible (paper §III-D):

  * ``encode(streams, params) -> (out_streams, header)`` — ``params`` may shape
    the encoding arbitrarily.
  * ``decode(out_streams, header) -> streams`` — **parameter-free**: everything
    decode needs must be in the (per-node, wire-stored) ``header`` bytes.

Codec ids are wire-stable; ``min_version`` implements the paper's codec-by-codec
format-version gating (§V-C).

Backends
--------
The *encode* side of a codec may additionally be implemented per execution
backend (``register_backend_codec``).  The engine's ``execute`` phase asks the
selected backend for an implementation of each resolved node; when one is
registered and its ``applies`` predicate accepts the concrete streams, it is
used — otherwise execution falls back to the host encoder.  Backend encoders
must be bit-exact with the host encoder (same output streams, same header);
decode is always the host (universal-decoder) path.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.reliability.faults import fault_point

from .message import Stream

__all__ = [
    "Atom",
    "InPort",
    "ParamSpec",
    "CodecSig",
    "ANY_STYPES",
    "FIXED_STYPES",
    "BYTE_STYPES",
    "NUMERIC_WIDTHS",
    "CodecSpec",
    "register_codec",
    "get_codec",
    "get_codec_by_id",
    "all_codecs",
    "BackendCodecImpl",
    "register_backend_codec",
    "get_backend_codec",
    "available_backends",
    "run_encode_via",
]

EncodeFn = Callable[..., Tuple[List[Stream], bytes]]
DecodeFn = Callable[[Sequence[Stream], bytes], List[Stream]]


# ------------------------------------------------------- stream-type signatures
#
# The static contract of a codec over the stream-type lattice (paper §III-C:
# edges are *typed*).  An ``Atom`` is one point of the lattice: ``(stype,
# width)`` with ``width is None`` meaning "any width legal for that stype".
# Signatures are declarative data + one pure transfer function, which lets
# ``repro.analysis`` abstractly interpret whole plans before a byte is
# compressed, and lets the conformance fuzz suite tie every declaration to the
# encoder's real acceptance behavior.

Atom = Tuple[int, Optional[int]]  # (int(SType), width-or-None)

# SType values, spelled as ints so signature declarations stay cheap to import:
# SERIAL=0, STRUCT=1, NUMERIC=2, STRING=3 (see core.message.SType).
ANY_STYPES = frozenset((0, 1, 2, 3))
FIXED_STYPES = frozenset((0, 1, 2))  # everything except STRING
BYTE_STYPES = frozenset((0,))  # SERIAL only
NUMERIC_WIDTHS = frozenset((1, 2, 4, 8))


@dataclass(frozen=True)
class InPort:
    """Acceptance constraint for one codec input edge.

    ``widths is None`` accepts any width legal for the stype; otherwise the
    concrete width must be in the set (an unknown width *may* match — the
    analyzer only reports definite errors).
    """

    stypes: frozenset
    widths: Optional[frozenset] = None

    def accepts(self, atom: Atom) -> bool:
        st, w = atom
        if st not in self.stypes:
            return False
        if self.widths is not None and w is not None and w not in self.widths:
            return False
        return True


@dataclass(frozen=True)
class ParamSpec:
    """Schema entry for one codec parameter (documentation + lint surface)."""

    name: str
    kind: str  # "int" | "int_list" | "str" | "float"
    required: bool = False
    choices: Optional[tuple] = None
    doc: str = ""


@dataclass(frozen=True)
class CodecSig:
    """Declared stream-type signature of a codec.

    * ``inputs`` — one ``InPort`` per declared input; for variadic codecs
      (``n_inputs == -1``) a single port applied to every wired input.
    * ``transfer(atoms, params, n_out)`` — the abstract output function: given
      one concrete ``Atom`` per input (widths may be ``None`` = unknown) plus
      the node's params and declared output count, return the list of output
      atoms, or ``None`` when the encoder would reject this combination (the
      place for cross-input constraints — concat's "all same type", adj_gap's
      equal widths — and params/width consistency like float_split's fmt).
      Must be pure and total (never raise).
    * ``params`` — declared parameter schema.
    * ``expansion`` — worst-case output-bytes/input-bytes bound across all
      outputs combined (drives the per-terminal-edge expansion diagnostic).
    * ``packed_outputs`` — output indices carrying entropy-packed (already
      incompressible) bytes; feeding them onward is flagged by the linter.
    """

    inputs: Tuple[InPort, ...]
    transfer: Callable[[Tuple[Atom, ...], dict, int], Optional[List[Atom]]]
    params: Tuple[ParamSpec, ...] = ()
    expansion: float = 1.0
    packed_outputs: Tuple[int, ...] = ()


@dataclass(frozen=True)
class CodecSpec:
    name: str
    codec_id: int  # wire-stable; never reuse
    encode: EncodeFn
    decode: DecodeFn
    n_inputs: int = 1  # -1 => variadic
    n_outputs: int = 1  # -1 => variadic (actual count recorded per node on wire)
    min_version: int = 1  # first format version that understands this codec
    doc: str = ""
    sig: Optional[CodecSig] = None  # stream-type signature (coverage-enforced)

    def run_encode(self, streams: Sequence[Stream], params: Optional[dict] = None):
        params = dict(params or {})
        if self.n_inputs >= 0 and len(streams) != self.n_inputs:
            raise ValueError(
                f"codec {self.name}: expected {self.n_inputs} inputs, got {len(streams)}"
            )
        outs, header = self.encode(list(streams), params)
        if self.n_outputs >= 0 and len(outs) != self.n_outputs:
            raise AssertionError(
                f"codec {self.name}: produced {len(outs)} outputs, spec says {self.n_outputs}"
            )
        if not isinstance(header, (bytes, bytearray)):
            raise AssertionError(f"codec {self.name}: header must be bytes")
        return [o.validate() for o in outs], bytes(header)

    def run_decode(self, out_streams: Sequence[Stream], header: bytes):
        ins = self.decode(list(out_streams), header)
        return [s.validate() for s in ins]


_BY_NAME: Dict[str, CodecSpec] = {}
_BY_ID: Dict[int, CodecSpec] = {}


def register_codec(spec: CodecSpec) -> CodecSpec:
    if spec.name in _BY_NAME:
        raise ValueError(f"duplicate codec name {spec.name!r}")
    if spec.codec_id in _BY_ID:
        raise ValueError(
            f"duplicate codec id {spec.codec_id} ({spec.name!r} vs"
            f" {_BY_ID[spec.codec_id].name!r})"
        )
    _BY_NAME[spec.name] = spec
    _BY_ID[spec.codec_id] = spec
    return spec


def get_codec(name: str) -> CodecSpec:
    _ensure_standard_library()
    try:
        return _BY_NAME[name]
    except KeyError:
        raise KeyError(f"unknown codec {name!r}; known: {sorted(_BY_NAME)}") from None


def get_codec_by_id(codec_id: int) -> CodecSpec:
    _ensure_standard_library()
    try:
        return _BY_ID[codec_id]
    except KeyError:
        raise KeyError(f"unknown codec id {codec_id}") from None


def all_codecs() -> Dict[str, CodecSpec]:
    _ensure_standard_library()
    return dict(_BY_NAME)


# ----------------------------------------------------------------- backends
HOST_BACKEND = "host"

ApplyFn = Callable[[Sequence[Stream], dict], bool]


@dataclass(frozen=True)
class BackendCodecImpl:
    """An alternate encoder for (backend, codec) — e.g. a Pallas kernel."""

    backend: str
    codec_name: str
    encode: EncodeFn
    applies: ApplyFn  # routability predicate over concrete (streams, params)


_BACKEND_IMPLS: Dict[Tuple[str, str], BackendCodecImpl] = {}


def register_backend_codec(
    backend: str,
    codec_name: str,
    encode: EncodeFn,
    applies: Optional[ApplyFn] = None,
) -> BackendCodecImpl:
    if backend == HOST_BACKEND:
        raise ValueError("'host' is the codec's own encoder; register others")
    key = (backend, codec_name)
    if key in _BACKEND_IMPLS:
        raise ValueError(f"duplicate backend impl {backend}:{codec_name}")
    impl = BackendCodecImpl(backend, codec_name, encode, applies or (lambda s, p: True))
    _BACKEND_IMPLS[key] = impl
    return impl


def get_backend_codec(backend: str, codec_name: str) -> Optional[BackendCodecImpl]:
    _ensure_standard_library()
    return _BACKEND_IMPLS.get((backend, codec_name))


def available_backends() -> List[str]:
    """'host' plus every backend with at least one registered encoder."""
    _ensure_standard_library()
    return [HOST_BACKEND] + sorted({b for b, _ in _BACKEND_IMPLS})


def run_encode_via(
    spec: CodecSpec,
    backend: str,
    streams: Sequence[Stream],
    params: Optional[dict] = None,
) -> Tuple[List[Stream], bytes]:
    """Encode through ``backend`` when an applicable impl exists, else host.

    Backend output passes the same postconditions as the host encoder.
    """
    params = dict(params or {})
    if backend != HOST_BACKEND:
        impl = get_backend_codec(backend, spec.name)
        if impl is not None and impl.applies(streams, params):
            # injectable device-kernel failure (repro.reliability): surfaces
            # exactly where a real kernel crash would, so the session-level
            # host failover sees the same thing either way
            fault_point(f"device.encode.{backend}.{spec.name}")
            outs, header = impl.encode(list(streams), params)
            if spec.n_outputs >= 0 and len(outs) != spec.n_outputs:
                raise AssertionError(
                    f"backend {backend}:{spec.name}: produced {len(outs)} outputs,"
                    f" spec says {spec.n_outputs}"
                )
            if not isinstance(header, (bytes, bytearray)):
                raise AssertionError(f"backend {backend}:{spec.name}: header must be bytes")
            return [o.validate() for o in outs], bytes(header)
    return spec.run_encode(streams, params)


_loaded = False
_load_lock = threading.RLock()


def _ensure_standard_library() -> None:
    """Lazily import the standard codec suite so `core` has no import cycle.

    Thread-safe: the loaded flag is only set after the import completes (a
    fresh process decoding a multi-chunk container hits this from the decode
    thread pool, all threads at once).
    """
    global _loaded
    if not _loaded:
        with _load_lock:
            if not _loaded:
                from repro import codecs as _  # noqa: F401  (registers on import)

                _loaded = True
