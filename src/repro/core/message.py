"""Typed message streams — the edges of the compression graph.

The paper (§III-A, §V-A) defines messages as elements of *message sets* and
approximates arbitrary sets with a 4-entry type system.  We mirror that:

  * ``SERIAL``   — opaque bytes.
  * ``STRUCT``   — fixed-size ``width``-byte records (``len(data) % width == 0``).
  * ``NUMERIC``  — host-endian unsigned/signed integers of width 1/2/4/8.
  * ``STRING``   — a sequence of byte strings (content bytes + a lengths array).

Host-side streams are numpy arrays (exact sizes).  The device path
(``repro.kernels``) uses the same layout with capacity-padded jnp buffers and a
dynamic length scalar; conversion helpers live here so both worlds agree.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field, replace
from typing import Iterable, List, Optional, Sequence

import numpy as np

__all__ = [
    "SType",
    "Stream",
    "serial",
    "numeric",
    "struct",
    "strings",
]


class SType(enum.IntEnum):
    """Wire-stable message type tags (values are serialized — never renumber)."""

    SERIAL = 0
    STRUCT = 1
    NUMERIC = 2
    STRING = 3


_NUMERIC_DTYPES = {
    1: np.dtype(np.uint8),
    2: np.dtype(np.uint16),
    4: np.dtype(np.uint32),
    8: np.dtype(np.uint64),
}
_SIGNED_DTYPES = {
    1: np.dtype(np.int8),
    2: np.dtype(np.int16),
    4: np.dtype(np.int32),
    8: np.dtype(np.int64),
}


@dataclass(frozen=True)
class Stream:
    """One message: a typed, immutable view over a flat buffer.

    ``data`` is always 1-D.  For SERIAL/STRUCT/STRING it is uint8; for NUMERIC
    it is the (un)signed integer dtype of ``width`` bytes.  ``lengths`` is only
    present for STRING streams (uint32 per-string byte lengths; ``data`` is the
    concatenated contents).
    """

    data: np.ndarray
    stype: SType
    width: int = 1
    lengths: Optional[np.ndarray] = None

    # ---------------------------------------------------------------- helpers
    @property
    def nbytes(self) -> int:
        n = int(self.data.nbytes)
        if self.stype == SType.STRING and self.lengths is not None:
            n += int(self.lengths.nbytes)
        return n

    @property
    def n_elts(self) -> int:
        if self.stype == SType.SERIAL:
            return int(self.data.size)
        if self.stype == SType.STRUCT:
            return int(self.data.size) // self.width
        if self.stype == SType.NUMERIC:
            return int(self.data.size)
        return int(self.lengths.size) if self.lengths is not None else 0

    def validate(self) -> "Stream":
        if self.data.ndim != 1:
            raise ValueError(f"stream data must be 1-D, got {self.data.shape}")
        if self.stype in (SType.SERIAL, SType.STRUCT, SType.STRING):
            if self.data.dtype != np.uint8:
                raise ValueError(f"{self.stype.name} stream must be uint8")
        if self.stype == SType.STRUCT:
            if self.width < 1 or self.data.size % self.width:
                raise ValueError(
                    f"struct({self.width}) stream length {self.data.size} not divisible"
                )
        if self.stype == SType.NUMERIC:
            if self.width not in _NUMERIC_DTYPES:
                raise ValueError(f"numeric width must be 1/2/4/8, got {self.width}")
            if self.data.dtype.itemsize != self.width:
                raise ValueError(
                    f"numeric({self.width}) carries dtype {self.data.dtype}"
                )
        if self.stype == SType.STRING:
            if self.lengths is None:
                raise ValueError("string stream requires lengths")
            if int(self.lengths.sum()) != self.data.size:
                raise ValueError("string lengths do not sum to content size")
        return self

    # ------------------------------------------------------- representations
    def content_bytes(self) -> bytes:
        """Raw little-endian bytes of the content buffer (for wire storage)."""
        arr = self.data
        if arr.dtype.byteorder == ">":  # normalise to LE — host-endian per paper
            arr = arr.astype(arr.dtype.newbyteorder("<"))
        return arr.tobytes()

    def as_serial(self) -> "Stream":
        """Reinterpret the content as opaque bytes (lossless view change)."""
        return Stream(
            np.frombuffer(self.content_bytes(), dtype=np.uint8), SType.SERIAL, 1
        )

    def as_unsigned(self) -> "Stream":
        """View NUMERIC data as unsigned (bit-preserving)."""
        if self.stype != SType.NUMERIC:
            raise ValueError("as_unsigned on non-numeric stream")
        return replace(self, data=self.data.view(_NUMERIC_DTYPES[self.width]))

    def as_signed(self) -> "Stream":
        if self.stype != SType.NUMERIC:
            raise ValueError("as_signed on non-numeric stream")
        return replace(self, data=self.data.view(_SIGNED_DTYPES[self.width]))

    def to_strings(self) -> List[bytes]:
        if self.stype != SType.STRING:
            raise ValueError("to_strings on non-string stream")
        out, off = [], 0
        buf = self.data.tobytes()
        for ln in self.lengths.tolist():
            out.append(buf[off : off + ln])
            off += ln
        return out

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"Stream({self.stype.name}, w={self.width}, n={self.n_elts},"
            f" {self.nbytes}B)"
        )


# ------------------------------------------------------------------ builders
def serial(data) -> Stream:
    if isinstance(data, (bytes, bytearray, memoryview)):
        arr = np.frombuffer(bytes(data), dtype=np.uint8)
    else:
        arr = np.asarray(data, dtype=np.uint8).ravel()
    return Stream(arr, SType.SERIAL, 1).validate()


def numeric(arr) -> Stream:
    """Build a NUMERIC stream.  Floats are bit-cast to same-width unsigned ints
    (the paper's numeric type is integral; float semantics are recovered by
    float-aware codecs such as ``float_split``)."""
    a = np.asarray(arr)
    if a.dtype.kind == "f":
        a = a.view(_NUMERIC_DTYPES[a.dtype.itemsize])
    if a.dtype.kind not in "iu":
        raise ValueError(f"numeric stream from dtype {a.dtype}?")
    if a.dtype.itemsize not in _NUMERIC_DTYPES:
        raise ValueError(f"unsupported numeric width {a.dtype.itemsize}")
    if a.dtype.byteorder == ">":
        a = a.astype(a.dtype.newbyteorder("<"))
    return Stream(np.ascontiguousarray(a.ravel()), SType.NUMERIC, a.dtype.itemsize).validate()


def struct(data, width: int) -> Stream:
    if isinstance(data, (bytes, bytearray, memoryview)):
        arr = np.frombuffer(bytes(data), dtype=np.uint8)
    else:
        arr = np.asarray(data, dtype=np.uint8).ravel()
    return Stream(arr, SType.STRUCT, width).validate()


def strings(items: Iterable[bytes]) -> Stream:
    items = list(items)
    lens = np.asarray([len(s) for s in items], dtype=np.uint32)
    content = np.frombuffer(b"".join(items), dtype=np.uint8)
    return Stream(content, SType.STRING, 1, lens).validate()


def from_wire(
    stype: SType, width: int, payload: bytes, lengths: Optional[np.ndarray]
) -> Stream:
    """Rebuild a stream from wire-format fields."""
    if stype == SType.NUMERIC:
        data = np.frombuffer(payload, dtype=_NUMERIC_DTYPES[width])
        return Stream(data, stype, width).validate()
    data = np.frombuffer(payload, dtype=np.uint8)
    return Stream(data, stype, width, lengths).validate()
