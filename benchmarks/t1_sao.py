"""Paper Table I: compression of SAO — the §IV worked example.

Columns mirror the paper (zstd -3 / xz -9 / OpenZL); zstd is unavailable
offline so zlib -6 stands in for the fast-LZ point (DESIGN.md §6)."""
from __future__ import annotations

from repro.codecs import sao_profile
from repro.core import serial

from .common import COMPETITORS, Result, csv_row, time_codec, time_openzl_plan
from .datasets import make_sao


def run(print_rows: bool = True):
    data = make_sao(50_000)
    rows = []
    for comp in ("zlib-6", "xz-9"):
        enc, dec = COMPETITORS[comp]
        rows.append(time_codec(comp, data, enc, dec))
    rows.append(time_openzl_plan("openzl-sao", sao_profile(), [serial(data)]))
    if print_rows:
        print("# Table I — SAO (paper: zstd-3 1.31x / xz-9 1.64x / OpenZL 2.06x)")
        print(f"#  raw = {len(data)} bytes")
        for r in rows:
            print(csv_row("t1_sao", r))
        oz = rows[-1]
        best_other = min(rows[:-1], key=lambda r: r.compressed_bytes)
        print(
            f"#  openzl ratio {oz.ratio:.2f} vs best-traditional"
            f" {best_other.name} {best_other.ratio:.2f}"
            f" -> {'REPRODUCED' if oz.ratio > best_other.ratio else 'NOT reproduced'}:"
            " OpenZL beats both traditional compressors on ratio"
        )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
