"""Train-once cache shared by fig6/t4/t3/fig7: trains an OpenZL compressor
per benchmark dataset (paper §VI-C protocol: train on a small sample, test on
the full data) and caches the serialized plans + stats on disk."""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.core import Compressor, Stream
from repro.core.message import SType
from repro.core.serialize import deserialize_plan, serialize_plan
from repro.training import train

from .datasets import benchmark_suite

CACHE = Path(__file__).resolve().parents[1] / "results" / "trained"
SMALL = os.environ.get("BENCH_SMALL", "1") == "1"
POP = int(os.environ.get("BENCH_POP", "12"))
GENS = int(os.environ.get("BENCH_GENS", "4"))
WORKERS = int(os.environ.get("BENCH_TRAIN_WORKERS", "0")) or None  # None=all CPUs


def _sample_streams(streams: List[Stream], frac: float) -> List[Stream]:
    """Training sample: a prefix slice of each stream (paper: 1-15% of data)."""
    out = []
    for s in streams:
        n = max(int(s.n_elts * frac), 64)
        if s.stype == SType.STRING:
            n = min(n, int(s.lengths.size))
            nb = int(s.lengths[:n].sum())
            out.append(Stream(s.data[:nb], s.stype, 1, s.lengths[:n]))
        elif s.stype == SType.NUMERIC:
            out.append(Stream(s.data[:n], s.stype, s.width))
        elif s.stype == SType.SERIAL:
            # serial blobs (e.g. CSV) must be cut at a record boundary
            raw = s.data[:n].tobytes()
            nl = raw.rfind(b"\n")
            cut = nl + 1 if nl > 0 else n
            out.append(Stream(s.data[:cut], s.stype, s.width))
        else:
            out.append(Stream(s.data[: n * s.width], s.stype, s.width))
    return out


def get_trained(force: bool = False) -> Dict[str, dict]:
    """{dataset: {streams, frontend, plans: [(Plan, est_size, est_time)],
                  stats, train_frac}}"""
    CACHE.mkdir(parents=True, exist_ok=True)
    suite = benchmark_suite(small=SMALL)
    out: Dict[str, dict] = {}
    for name, streams, frontend in suite:
        meta_path = CACHE / f"{name}.json"
        entry = {"streams": streams, "frontend": frontend}
        train_frac = 0.05 if name not in ("binance",) else 0.15
        if meta_path.exists() and not force:
            meta = json.loads(meta_path.read_text())
            plans = []
            for i in range(meta["n_points"]):
                blob = (CACHE / f"{name}_{i}.ozp").read_bytes()
                plan, _ = deserialize_plan(blob)
                plans.append((plan, meta["sizes"][i], meta["times"][i]))
            entry.update(plans=plans, stats=meta["stats"], train_frac=meta["train_frac"])
        else:
            sample = _sample_streams(streams, train_frac)
            # csv frontends need raw bytes; sampling serial streams is fine
            tc = train(
                [sample], frontend, pop_size=POP, generations=GENS, workers=WORKERS
            )
            plans = [(p, sz, tm) for p, sz, tm in tc.pareto_plans()]
            meta = {
                "n_points": len(plans),
                "sizes": [sz for _, sz, _ in plans],
                "times": [tm for _, _, tm in plans],
                "stats": tc.stats,
                "train_frac": train_frac,
            }
            for i, (plan, _, _) in enumerate(plans):
                (CACHE / f"{name}_{i}.ozp").write_bytes(serialize_plan(plan))
            meta_path.write_text(json.dumps(meta))
            entry.update(plans=plans, stats=tc.stats, train_frac=train_frac)
        out[name] = entry
    return out
