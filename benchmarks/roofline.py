"""Roofline analysis (assignment deliverable g): derive compute / memory /
collective terms per (arch × shape × mesh) from the dry-run artifacts.

Hardware model (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.

Semantics (calibrated, see tests/test_roofline.py): ``cost_analysis()`` of an
SPMD executable reports PER-DEVICE flops / bytes accessed, and collective ops
in post-SPMD HLO carry per-device transfer shapes.  So:

    compute    = flops_per_device / PEAK         (== global/(chips*peak))
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / LINK_BW

MODEL_FLOPS is the analytic 6*N*D (train) / 2*N*D (inference) useful-work
count; MODEL_FLOPS / (flops_pd * chips) exposes remat/redundancy waste.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import all_archs, get_arch

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = Path(__file__).resolve().parents[1] / "results"


# ------------------------------------------------------- model flops (6ND)
def _lm_flops(arch_id: str, shape_name: str) -> float:
    spec = get_arch(arch_id)
    cfg = spec.model_cfg
    dims = spec.shape(shape_name).dims
    B, S = dims["global_batch"], dims["seq_len"]
    D, L, F = cfg.d_model, cfg.n_layers, cfg.d_ff
    H, KV, dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    attn_p = D * H * dh + 2 * D * KV * dh + H * dh * D
    if cfg.is_moe:
        ffn_p = D * cfg.n_experts + cfg.top_k * 3 * D * F  # router + active experts
    else:
        ffn_p = 3 * D * F
    n_active = L * (attn_p + ffn_p) + D * cfg.vocab  # + head
    kind = spec.shape(shape_name).kind
    if kind == "train":
        T = B * S
        return 6.0 * n_active * T + 3 * (4.0 * S * S * H * dh * B * L)
    if kind == "prefill":
        T = B * S
        return 2.0 * n_active * T + 4.0 * S * S * H * dh * B * L
    # decode: one token, context = cache length
    ctx = min(S, cfg.sliding_window) if cfg.sliding_window else S
    return 2.0 * n_active * B + 4.0 * ctx * H * dh * B * L


def _gnn_flops(shape_name: str) -> float:
    spec = get_arch("graphcast")
    cfg = spec.model_cfg
    dims = spec.shape(shape_name).dims
    H, L = cfg.d_hidden, cfg.n_layers
    if shape_name == "molecule":
        N = dims["batch"] * dims["n_nodes"]
        E = dims["batch"] * dims["n_edges"]
    elif shape_name == "minibatch_lg":
        N, E = dims["pad_nodes"], dims["pad_edges"]
    else:
        N, E = dims["n_nodes"], dims["n_edges"]
    d_in, d_out = dims["d_feat"], dims["d_out"]
    enc = 2.0 * N * (d_in * H + H * H) + 2.0 * E * (4 * H + H * H)
    per_layer = 2.0 * E * (3 * H * H + H * H) + 2.0 * N * (2 * H * H + H * H)
    dec = 2.0 * N * (H * H + H * d_out)
    return 3.0 * (enc + L * per_layer + dec)  # train: fwd+bwd


def _recsys_flops(arch_id: str, shape_name: str) -> float:
    spec = get_arch(arch_id)
    cfg = spec.model_cfg
    shape = spec.shape(shape_name)
    B = shape.dims.get("batch", 1)
    NC = shape.dims.get("n_candidates", 0)
    mult = 3.0 if shape.kind == "train" else 1.0
    if arch_id == "xdeepfm":
        m, d = cfg.n_sparse, cfg.embed_dim
        eff_B = NC if shape.kind == "retrieval" else B
        cin = 0.0
        hk = m
        for h in cfg.cin_layers:
            cin += eff_B * (hk * m * d + 2 * hk * m * d * h / d * d)  # z + conv
            cin += 2.0 * eff_B * hk * m * h * d
            hk = h
        sizes = [m * d, *cfg.mlp_sizes, 1]
        dnn = 2.0 * eff_B * sum(a * b for a, b in zip(sizes, sizes[1:]))
        return mult * (cin + dnn)
    if arch_id == "dcn-v2":
        D = cfg.d_input
        eff_B = NC if shape.kind == "retrieval" else B
        cross = 2.0 * eff_B * cfg.n_cross_layers * D * D
        sizes = [D, *cfg.mlp_sizes]
        deep = 2.0 * eff_B * sum(a * b for a, b in zip(sizes, sizes[1:]))
        return mult * (cross + deep)
    if arch_id == "sasrec":
        d, S, nb = cfg.embed_dim, cfg.seq_len, cfg.n_blocks
        eff_B = 1 if shape.kind == "retrieval" else B
        blocks = eff_B * nb * (2.0 * 4 * S * d * d + 2.0 * 2 * S * S * d + 2.0 * 8 * S * d * d)
        if shape.kind == "retrieval":
            logits = 2.0 * NC * d
        elif shape.kind == "train":
            logits = 2.0 * B * B * d  # in-batch softmax
        else:
            logits = 0.0  # serve: encode only
        return mult * (blocks + logits)
    # mind
    d, S, K, it = cfg.embed_dim, cfg.seq_len, cfg.n_interests, cfg.capsule_iters
    eff_B = 1 if shape.kind == "retrieval" else B
    routing = 2.0 * eff_B * it * 2 * S * K * d + 2.0 * eff_B * S * d * d
    if shape.kind == "retrieval":
        logits = 2.0 * K * NC * d
    elif shape.kind == "train":
        logits = 2.0 * B * B * d
    else:
        logits = 0.0
    return mult * (routing + logits)


def model_flops(arch_id: str, shape_name: str) -> float:
    family = get_arch(arch_id).family
    if family == "lm":
        return _lm_flops(arch_id, shape_name)
    if family == "gnn":
        return _gnn_flops(shape_name)
    return _recsys_flops(arch_id, shape_name)


# ------------------------------------------------------------------- table
def analyze(mesh_tag: str = "pod16x16", variant: str = "") -> Dict[str, dict]:
    suffix = f"__{mesh_tag}" + (f"__{variant}" if variant else "")
    out = {}
    for f in sorted((RESULTS / "dryrun").glob(f"*{suffix}.json")):
        if not variant and ("__opt" in f.name or "__gc" in f.name or "__unroll" in f.name):
            continue
        rec = json.loads(f.read_text())
        key = f"{rec['arch']}×{rec['shape']}"
        if rec["status"] == "skipped":
            out[key] = {"status": "skipped", "reason": rec["skip_reason"]}
            continue
        if rec["status"] != "ok":
            out[key] = {"status": "error", "error": rec.get("error", "")[:200]}
            continue
        chips = rec["n_devices"]
        flops_pd = rec["cost"].get("flops", 0.0)
        bytes_pd = rec["cost"].get("bytes accessed", 0.0)
        coll_pd = rec["collectives"]["total"]
        # XLA cost_analysis counts while-loop bodies ONCE: scanned models
        # (lm/gnn layer scan) undercount by ~n_layers.  Validated against a
        # fully-unrolled compile of yi-9b train_4k: loop-flops × 48 = 1.28e15
        # vs unrolled 1.19e15 (+7.5%, the non-loop prologue counted L times).
        # Recsys models have no layer scan — no correction.
        scan_factor = 1.0
        if rec["family"] in ("lm", "gnn") and "unroll" not in rec.get("variant", ""):
            cfgs = get_arch(rec["arch"])
            scan_factor = float(cfgs.model_cfg.n_layers)
            if "opt" in rec.get("variant", "") and rec["kind"] == "train":
                scan_factor *= 4.0  # microbatch accumulation scan
        flops_pd *= scan_factor
        bytes_pd *= scan_factor  # bytes in the loop body likewise undercounted
        t_compute = flops_pd / PEAK_FLOPS
        t_memory = bytes_pd / HBM_BW
        t_coll = coll_pd / LINK_BW
        dominant = max(
            ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
            key=lambda kv: kv[1],
        )[0]
        mf = model_flops(rec["arch"], rec["shape"])
        hlo_total = flops_pd * chips
        useful = mf / hlo_total if hlo_total else 0.0
        bound = max(t_compute, t_memory, t_coll)
        # the memory term uses XLA-CPU 'bytes accessed', which is PRE-FUSION
        # (every op's operands counted) — an upper bound on HBM traffic, not
        # a measurement.  bound_cc uses only the two reliable terms.
        bound_cc = max(t_compute, t_coll)
        ideal = mf / (chips * PEAK_FLOPS)
        out[key] = {
            "status": "ok",
            "chips": chips,
            "t_compute_s": t_compute,
            "t_memory_ub_s": t_memory,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "dominant_cc": "compute" if t_compute >= t_coll else "collective",
            "model_flops": mf,
            "hlo_flops_total": hlo_total,
            "useful_flops_ratio": useful,
            "roofline_fraction_ub": (ideal / bound) if bound else 0.0,
            "roofline_fraction_cc": (ideal / bound_cc) if bound_cc else 0.0,
            "mem_per_device_gib": rec["memory"].get("per_device_total", 0) / 2**30,
            "collective_bytes_pd": coll_pd,
        }
    return out


def main():
    for mesh, variant in (("pod16x16", ""), ("pod16x16", "opt")):
        table = analyze(mesh, variant)
        if not table:
            continue
        tag = mesh + (f"_{variant}" if variant else "")
        (RESULTS / f"roofline_{tag}.json").write_text(json.dumps(table, indent=1))
        print(f"# Roofline table ({tag}; terms in ms, per step)")
        print(
            "cell,compute_ms,memory_ub_ms,collective_ms,dominant_cc,"
            "useful_flops_ratio,roofline_frac_cc,mem_gib_per_dev"
        )
        for key, row in table.items():
            if row["status"] != "ok":
                print(f"{key},skip,,,{row.get('reason', row.get('error',''))[:60]},,,")
                continue
            print(
                f"{key},{row['t_compute_s']*1e3:.3f},{row['t_memory_ub_s']*1e3:.3f},"
                f"{row['t_collective_s']*1e3:.3f},{row['dominant_cc']},"
                f"{row['useful_flops_ratio']:.3f},{row['roofline_fraction_cc']:.3f},"
                f"{row['mem_per_device_gib']:.2f}"
            )


if __name__ == "__main__":
    main()
