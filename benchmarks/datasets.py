"""Synthetic stand-ins for the paper's benchmark datasets (Table II).

The real corpora (Binance candles, NYC TLC trips, ERA5 reanalysis, US census
CSVs, Silesia SAO) are not available offline; these generators reproduce the
*statistical structure the paper's compressors exploit*: sorted timestamps,
correlated random-walk prices, bounded/low-cardinality fields, spatially
smooth float grids, categorical CSV columns.  Each returns (name, inputs,
frontend) ready for the trainer.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core import Stream, numeric, serial
from repro.training import (
    CsvFrontend,
    Frontend,
    MultiStreamFrontend,
    NumericFrontend,
    StructFrontend,
)

from repro.codecs.profiles import SAO_FIELDS, SAO_HEADER_BYTES


# ----------------------------------------------------------------- SAO (§IV)
def make_sao(n_records: int = 50_000, seed: int = 0) -> bytes:
    """Star catalogue: sorted right-ascension f64, bounded declination f64,
    low-cardinality spectral/magnitude/motion fields (paper §IV)."""
    rng = np.random.default_rng(seed)
    rec = np.zeros(
        n_records,
        dtype=[("sra", "<f8"), ("sdec", "<f8"), ("is", "<u2"), ("mag", "<i2"),
               ("xrpm", "<f4"), ("xdpm", "<f4")],
    )
    rec["sra"] = np.sort(rng.uniform(0, 2 * np.pi, n_records))
    rec["sdec"] = rng.uniform(-np.pi / 2, np.pi / 2, n_records)
    rec["is"] = rng.choice(64, n_records, p=_zipf_p(64, 1.3, rng))
    rec["mag"] = rng.choice(np.arange(-149, 1450, 10, dtype=np.int16), n_records)
    rec["xrpm"] = rng.choice(np.round(np.linspace(-0.5, 0.5, 997), 5).astype(np.float32), n_records)
    rec["xdpm"] = rng.choice(np.round(np.linspace(-0.5, 0.5, 1009), 5).astype(np.float32), n_records)
    return b"\x00" * SAO_HEADER_BYTES + rec.tobytes()


def sao_frontend() -> Frontend:
    return StructFrontend(widths=tuple(w for _, w in SAO_FIELDS))


def _zipf_p(n, a, rng):
    p = np.arange(1, n + 1, dtype=np.float64) ** -a
    return p / p.sum()


# ------------------------------------------------- Parquet-like (binance/tlc)
def make_binance_columns(n_rows: int = 120_000, seed: int = 0) -> List[Stream]:
    """1-minute candlesticks: sorted ms timestamps, random-walk OHLC with
    high intra-row correlation, heavy-tailed volumes/trade-counts."""
    rng = np.random.default_rng(seed)
    ts = (1_500_000_000_000 + np.arange(n_rows, dtype=np.int64) * 60_000
          + rng.integers(0, 3, n_rows))
    mid = 30_000 * np.exp(np.cumsum(rng.normal(0, 2e-4, n_rows)))
    spread = np.abs(rng.normal(0, 5e-4, (4, n_rows)))
    o = np.round(mid * (1 + spread[0]), 2)
    h = np.round(mid * (1 + spread[1] + 5e-4), 2)
    l = np.round(mid * (1 - spread[2] - 5e-4), 2)
    c = np.round(mid * (1 + spread[3] - 2e-4), 2)
    vol = np.round(rng.pareto(1.5, n_rows) * 10, 3)
    trades = (rng.pareto(1.2, n_rows) * 50).astype(np.int32)
    return [
        numeric(ts),
        numeric(o), numeric(h), numeric(l), numeric(c),
        numeric(vol), numeric(trades.astype(np.int32)),
    ]


def make_tlc_columns(n_rows: int = 150_000, seed: int = 1) -> List[Stream]:
    """Taxi trips: near-sorted pickup times, quantized fares/distances,
    low-cardinality location/vendor/passenger fields."""
    rng = np.random.default_rng(seed)
    pickup = np.sort(1_735_000_000 + (rng.pareto(2.0, n_rows) * 5e6).astype(np.int64) % 7_800_000)
    dur = (rng.lognormal(6.2, 0.8, n_rows)).astype(np.int32)
    dropoff = pickup + dur
    dist = np.round(rng.lognormal(0.8, 0.9, n_rows), 2)
    fare = np.round(3.0 + dist * 2.5 + rng.normal(0, 1, n_rows).clip(0), 2)
    tip = np.round(fare * rng.choice([0, 0.1, 0.15, 0.2, 0.25], n_rows), 2)
    loc_p = rng.choice(265, n_rows, p=_zipf_p(265, 1.1, rng)).astype(np.int16)
    loc_d = rng.choice(265, n_rows, p=_zipf_p(265, 1.1, rng)).astype(np.int16)
    vendor = rng.choice(3, n_rows).astype(np.int8)
    passengers = rng.choice([1, 1, 1, 2, 2, 3, 5], n_rows).astype(np.int8)
    return [
        numeric(pickup), numeric(dropoff),
        numeric(dist), numeric(fare), numeric(tip),
        numeric(loc_p.astype(np.uint16)), numeric(loc_d.astype(np.uint16)),
        numeric(vendor.astype(np.uint8)), numeric(passengers.astype(np.uint8)),
    ]


# ------------------------------------------------------- GRIB-like (ERA5)
def make_era5_grid(
    n_snapshots: int = 24, ny: int = 180, nx: int = 360, seed: int = 2,
    smooth: float = 8.0, kind: str = "wind",
) -> np.ndarray:
    """Spatially smooth f32 fields with temporal persistence (reanalysis
    structure).  'snow'-like fields are mostly-zero + bounded."""
    rng = np.random.default_rng(seed)
    k = int(smooth)
    base = rng.normal(0, 1, (ny + k, nx + k))
    kernel = np.ones(k) / k
    sm = np.apply_along_axis(lambda r: np.convolve(r, kernel, "same"), 1, base)
    sm = np.apply_along_axis(lambda c: np.convolve(c, kernel, "same"), 0, sm)[:ny, :nx]
    fields = []
    cur = sm
    for t in range(n_snapshots):
        cur = 0.95 * cur + 0.05 * rng.normal(0, 1, (ny, nx))
        f = cur * 10.0
        if kind == "snow":
            f = np.maximum(f - 15.0, 0.0)  # sparse
        elif kind == "precip":
            f = np.maximum(f - 5.0, 0.0) * 1e-3
        fields.append(f.astype(np.float32))
    return np.stack(fields)


# ------------------------------------------------------------ CSV (census)
def make_ppmf_csv(n_rows: int = 120_000, seed: int = 3) -> bytes:
    """Census microdata: categorical codes, bounded ints, constant columns."""
    rng = np.random.default_rng(seed)
    state = rng.choice(56, n_rows, p=_zipf_p(56, 0.8, rng))
    county = rng.choice(999, n_rows, p=_zipf_p(999, 1.0, rng))
    age = rng.integers(0, 116, n_rows)
    sex = rng.choice([1, 2], n_rows)
    race = rng.choice(63, n_rows, p=_zipf_p(63, 1.6, rng))
    hisp = rng.choice([1, 2], n_rows, p=[0.81, 0.19])
    rtype = np.full(n_rows, 3)
    gqtype = rng.choice([0, 101, 201, 301, 401, 501], n_rows, p=[0.96, 0.01, 0.01, 0.005, 0.005, 0.01])
    rows = [
        b"%d,%03d,%d,%d,%d,%d,%d,%d"
        % (state[i], county[i], age[i], sex[i], race[i], hisp[i], rtype[i], gqtype[i])
        for i in range(n_rows)
    ]
    return b"EPNUM,COUNTY,QAGE,QSEX,CENRACE,CENHISP,RTYPE,GQTYPE"[:0] + b"\n".join(rows) + b"\n"


def make_psam_csv(n_rows: int = 80_000, seed: int = 4) -> bytes:
    """ACS PUMS-ish: wider mix of numeric + empty + coded columns."""
    rng = np.random.default_rng(seed)
    serialno = 2023000000000 + np.cumsum(rng.integers(1, 40, n_rows).astype(np.int64))
    puma = rng.choice(2400, n_rows, p=_zipf_p(2400, 0.7, rng))
    wgtp = rng.integers(1, 300, n_rows)
    np_ = rng.choice(9, n_rows, p=_zipf_p(9, 1.4, rng))
    bds = rng.choice(6, n_rows, p=_zipf_p(6, 1.1, rng))
    rnt = np.where(rng.random(n_rows) < 0.6, rng.integers(100, 4000, n_rows), 0)
    val = np.where(rng.random(n_rows) < 0.55, rng.integers(10, 999, n_rows) * 1000, 0)
    rows = [
        b"%d,%d,%d,%d,%d,%s,%s"
        % (
            serialno[i], puma[i], wgtp[i], np_[i], bds[i],
            (b"%d" % rnt[i]) if rnt[i] else b"",
            (b"%d" % val[i]) if val[i] else b"",
        )
        for i in range(n_rows)
    ]
    return b"\n".join(rows) + b"\n"


# --------------------------------------------------------------- the suite
def benchmark_suite(small: bool = False) -> List[Tuple[str, List[Stream], Frontend]]:
    """(name, input streams, frontend) per dataset, mirroring Table II."""
    f = 0.25 if small else 1.0

    def sz(n):
        return max(int(n * f), 2000)

    out = []
    bin_cols = make_binance_columns(sz(120_000))
    out.append(("binance", bin_cols, MultiStreamFrontend(k=len(bin_cols))))
    tlc_cols = make_tlc_columns(sz(150_000))
    out.append(("tlc", tlc_cols, MultiStreamFrontend(k=len(tlc_cols))))
    era5_seeds = {"wind": 11, "pressure": 22, "snow": 33, "flux": 44, "precip": 55}
    for kind in ("wind", "pressure", "snow", "flux", "precip"):
        # NOTE: fixed seeds — hash(str) is per-process randomized and made
        # earlier benchmark runs non-reproducible
        grid = make_era5_grid(n_snapshots=max(int(24 * f), 4), kind=kind,
                              seed=era5_seeds[kind])
        out.append((f"era5_{kind}", [numeric(grid.reshape(-1))], NumericFrontend(width=4)))
    out.append(("ppmf_person", [serial(make_ppmf_csv(sz(120_000)))], CsvFrontend(n_cols=8)))
    out.append(("psam_h", [serial(make_psam_csv(sz(80_000)))], CsvFrontend(n_cols=7)))
    return out


def streams_to_bytes(streams: List[Stream]) -> bytes:
    """Serialize multi-stream inputs to a flat byte blob for byte-oriented
    competitors (zlib/lzma see exactly the same information)."""
    return b"".join(s.content_bytes() for s in streams)
