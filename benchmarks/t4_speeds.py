"""Paper Table IV: mean compression/decompression speeds (MiB/s) across the
Fig. 6 datasets, per compressor."""
from __future__ import annotations

import numpy as np

from .fig6_ratios import run as fig6_run


def run(print_rows: bool = True):
    all_results = fig6_run(print_rows=False)
    by_comp = {}
    for rows in all_results.values():
        for r in rows:
            by_comp.setdefault(r.name, []).append(r)
    out = []
    for comp, rs in by_comp.items():
        c = float(np.mean([r.c_mibs for r in rs]))
        d = float(np.mean([r.d_mibs for r in rs]))
        ratio = float(np.mean([r.ratio for r in rs]))
        out.append((comp, c, d, ratio))
        if print_rows:
            print(
                f"t4_speeds/{comp},{1e6 / max(c, 1e-9):.1f},"
                f"mean_c_mibs={c:.2f};mean_d_mibs={d:.2f};mean_ratio={ratio:.3f}"
            )
    if print_rows:
        print(
            "# paper Table IV: zlib-6 52.5/715, zstd-19 6.07/2820, xz-9 6.14/314,"
            " nncp 0.0025/0.0025, cmix 0.001/0.001, openzl 142/323 MiB/s"
        )
        print(
            "# (this container: single CPU core, numpy/python kernels —"
            " compare SHAPE of the ordering, not absolute numbers)"
        )
    return out


def main():
    run()


if __name__ == "__main__":
    main()
