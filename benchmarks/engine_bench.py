"""Engine-phase benchmarks: resolve-cache hit rate, host vs device backend,
and chunked-parallel throughput.

Rows (CSV, appended to benchmarks/run.py output):
    engine/resolve_cache      — selector profile compressed repeatedly;
                                derived shows the cache hit rate
    engine/host_single        — one-shot host compression of the big input
    engine/device_single      — same plan via the device backend
    engine/chunked_host       — chunk_bytes split, thread-pool execution;
                                derived shows the speedup vs host_single
                                (acceptance floor: >= 1.5x on >= 32 MiB)

The input is a >= 32 MiB synthetic numeric stream (delta-friendly cumsum) and
the plan is delta -> transpose -> zlib, whose heavy stages release the GIL —
which is exactly what chunked compression exploits.
"""
from __future__ import annotations

import os
import time

import numpy as np

from repro.core import (
    CompressionCtx,
    compress,
    decompress,
    numeric,
    pipeline,
    resolve_cache_clear,
    resolve_cache_info,
)

MIB = 1 << 20
TOTAL_BYTES = int(os.environ.get("REPRO_ENGINE_BENCH_MIB", "32")) * MIB
CHUNK_BYTES = 4 * MIB


def _big_input():
    rng = np.random.default_rng(0)
    n = TOTAL_BYTES // 4
    return numeric(np.cumsum(rng.integers(0, 50, n, dtype=np.int64)).astype(np.uint32))


def _time_compress(plan, stream, **kw):
    t0 = time.perf_counter()
    frame = compress(plan, stream, **kw)
    return time.perf_counter() - t0, frame


def run(print_rows: bool = True):
    rows = []

    # -- resolve cache: selector expansion amortized across calls ------------
    from repro.codecs import generic_profile

    resolve_cache_clear()
    prof = generic_profile()
    small = numeric(np.cumsum(np.random.default_rng(1).integers(0, 9, 1 << 16)).astype(np.uint32))
    n_calls = 6
    t0 = time.perf_counter()
    for _ in range(n_calls):
        compress(prof, small)
    per_call_us = (time.perf_counter() - t0) / n_calls * 1e6
    info = resolve_cache_info()
    top_level_hits = n_calls - 1  # first call misses, the rest reuse
    hit_rate = info["hits"] / max(info["hits"] + info["misses"], 1)
    rows.append(
        f"engine/resolve_cache,{per_call_us:.1f},"
        f"hit_rate={hit_rate:.2f};hits={info['hits']};misses={info['misses']};"
        f"calls={n_calls};top_level_hits={top_level_hits}"
    )

    # -- backend + chunked throughput on the big input -----------------------
    stream = _big_input()
    raw_mib = stream.nbytes / MIB
    plan = pipeline("delta", "transpose", ("zlib_backend", {"level": 1}))

    t_host, frame_host = _time_compress(plan, stream)
    assert decompress(frame_host)[0].content_bytes() == stream.content_bytes()
    rows.append(
        f"engine/host_single,{t_host*1e6:.1f},"
        f"c_mibs={raw_mib/t_host:.2f};size={len(frame_host)};input_mib={raw_mib:.0f}"
    )

    # warm the jit caches so device_single measures steady state
    warm = numeric(stream.data[: 1 << 16])
    _time_compress(pipeline("delta", "transpose"), warm, backend="device")
    t_dev, frame_dev = _time_compress(plan, stream, backend="device")
    assert frame_dev == frame_host, "device frame must be byte-identical"
    rows.append(
        f"engine/device_single,{t_dev*1e6:.1f},"
        f"c_mibs={raw_mib/t_dev:.2f};size={len(frame_dev)};bit_exact=1"
    )

    t_chunk, frame_chunk = _time_compress(plan, stream, chunk_bytes=CHUNK_BYTES)
    assert decompress(frame_chunk)[0].content_bytes() == stream.content_bytes()
    speedup = t_host / t_chunk
    rows.append(
        f"engine/chunked_host,{t_chunk*1e6:.1f},"
        f"c_mibs={raw_mib/t_chunk:.2f};size={len(frame_chunk)};"
        f"chunk_mib={CHUNK_BYTES/MIB:.0f};speedup={speedup:.2f};"
        f"workers={os.cpu_count()}"
    )

    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
