"""Engine-phase benchmarks: resolve-cache hit rate, host vs device backend,
chunked-parallel throughput — and the codec hot-path section.

Rows (CSV, appended to benchmarks/run.py output):
    engine/resolve_cache      — selector profile compressed repeatedly;
                                derived shows the cache hit rate
    engine/host_single        — one-shot host compression of the big input
    engine/device_single      — same plan via the device backend
    engine/chunked_host       — chunk_bytes split, thread-pool execution;
                                derived shows the speedup vs host_single
                                (acceptance floor: >= 1.5x on >= 32 MiB)

``--codecs`` additionally benchmarks the lz77/huffman/fse hot paths on three
canonical corpora — "text" (zipfian prose, 2^17-word vocabulary, exponent
1.05: natural-language-like statistics), "log" (structured log lines,
OpenZL's home turf) and "graph" (SNAP-style tab-separated edge list,
power-law degrees) — at 1 MiB and 16 MiB, encode and decode, then runs the
profile shoot-out on the graph corpus: ``graph:`` vs the generic ``text`` /
``numeric`` / ``generic`` profiles, ratio and MiB/s, with a hard floor that
the structure-aware ``graph:`` profile wins on ratio.  ``--json``
writes the results to ``results/BENCH_codecs.json``; when
``results/BENCH_codecs_baseline.json`` (the pre-vectorization measurements,
same generators, same host) is present, per-row speedups are recorded so the
perf trajectory of the serial-hot-path work stays on the record.

``--stream`` benchmarks the session/streaming file path against the one-shot
in-memory path on a log corpus (``REPRO_STREAM_BENCH_MIB``, default 64):
each measurement runs in a subprocess so ``ru_maxrss`` isolates peak memory,
reported as a delta over a no-op import baseline.  The streaming rows should
show peak memory ~ window × chunk (not input size) at one-shot-or-better
warm-session throughput.  With ``--json`` the results land in
``results/BENCH_stream.json``.

``--train`` benchmarks the parallel trainer (``repro train``) on a synthetic
CSV corpus (``REPRO_TRAIN_BENCH_KIB``, default 512): one full training run at
``workers=1`` and one at ``workers=4``, asserting the emitted Pareto plans
are byte-identical (the trainer's determinism contract) and recording the
wall-clock speedup.  With ``--json`` the results land in
``results/BENCH_train.json``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

from repro.core import (
    CompressionCtx,
    compress,
    decompress,
    numeric,
    pipeline,
    resolve_cache_clear,
    resolve_cache_info,
)

MIB = 1 << 20
TOTAL_BYTES = int(os.environ.get("REPRO_ENGINE_BENCH_MIB", "32")) * MIB
CHUNK_BYTES = 4 * MIB
RESULTS_DIR = Path(__file__).resolve().parents[1] / "results"


# ------------------------------------------------------ canonical corpora
def synth_text(nbytes: int, seed: int = 0) -> bytes:
    """Zipfian prose: 2^17-word vocabulary, exponent 1.05 (Zipf's law for
    natural language), word lengths 2-11.  Fully vectorized assembly."""
    vocab_size = 1 << 17
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, 12, vocab_size).astype(np.int64)
    letters = rng.integers(97, 123, int(lens.sum())).astype(np.uint8)
    bounds = np.concatenate([[0], np.cumsum(lens)])
    w = 1.0 / np.arange(1, vocab_size + 1) ** 1.05
    w /= w.sum()
    idx = rng.choice(vocab_size, size=nbytes // 4 + 16, p=w)
    wl = lens[idx]
    ends = np.cumsum(wl + 1)
    starts = ends - 1 - wl
    out = np.full(int(ends[-1]), 32, np.uint8)
    intra = np.arange(int(wl.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(wl) - wl, wl
    )
    out[np.repeat(starts, wl) + intra] = letters[np.repeat(bounds[idx], wl) + intra]
    return out[:nbytes].tobytes().ljust(nbytes, b" ")


def synth_log(nbytes: int, seed: int = 0) -> bytes:
    """Structured log lines: timestamps, hex ids, k=v fields — the
    structured-data shape the paper's graph model targets."""
    rng = np.random.default_rng(seed)
    levels = [b"INFO", b"WARN", b"DEBUG", b"ERROR"]
    services = [b"auth", b"billing", b"ingest", b"frontend", b"search", b"cache"]
    verbs = [b"handled", b"rejected", b"queued", b"retried", b"flushed"]
    lines = []
    total = 0
    t = 1753862400.0
    while total < nbytes + 256:
        t += float(rng.exponential(0.05))
        line = (
            b"2026-07-30T%02d:%02d:%06.3fZ %s %s req=%016x user=%08d %s in"
            b" %dus path=/api/v2/%s/%d\n"
            % (
                int(t // 3600) % 24,
                int(t // 60) % 60,
                t % 60,
                levels[int(rng.choice(4, p=[0.7, 0.15, 0.1, 0.05]))],
                services[int(rng.integers(6))],
                int(rng.integers(0, 1 << 63)),
                int(rng.integers(0, 10**8)),
                verbs[int(rng.integers(5))],
                int(rng.integers(10, 99999)),
                services[int(rng.integers(6))],
                int(rng.integers(0, 9999)),
            )
        )
        lines.append(line)
        total += len(line)
    return b"".join(lines)[:nbytes]


def synth_edges(nbytes: int, seed: int = 0) -> bytes:
    """SNAP-style text edge list: ``# comment`` header then sorted ``u\\tv``
    lines, power-law target popularity (hub nodes shared across adjacency
    lists — the overlap Zuckerli-style reference coding exploits)."""
    rng = np.random.default_rng(seed)
    n_edges = nbytes // 8 + 64
    while True:  # dedup + short ids shrink the text: grow until it covers
        n_nodes = max(n_edges // 16, 64)
        w = 1.0 / np.arange(1, n_nodes + 1) ** 1.1
        w /= w.sum()
        dst = rng.choice(n_nodes, size=n_edges, p=w).astype(np.uint64)
        src = np.sort(rng.integers(0, n_nodes, n_edges)).astype(np.uint64)
        pairs = np.unique(np.stack([src, dst], axis=1), axis=0)
        head = (
            b"# SNAP-style synthetic graph  Nodes: %d  Edges: %d\n"
            b"# FromNodeId\tToNodeId\n" % (n_nodes, len(pairs))
        )
        body = b"\n".join(b"%d\t%d" % (u, v) for u, v in pairs)
        raw = head + body + b"\n"
        if len(raw) >= nbytes:
            return raw[:nbytes]
        n_edges += n_edges // 2


def run_codecs(sizes_mib=(1, 16, 64), emit_json=False, print_rows=True):
    """Benchmark the lz77/huffman/fse hot paths; optionally write JSON.

    Besides end-to-end MiB/s, each row carries a per-stage wall-clock
    breakdown (match_find / table_build / bit_io, seconds) from one extra
    instrumented rep, so a throughput cliff can be *attributed* to a stage
    rather than just observed.
    """
    from repro.codecs import _stages
    from repro.codecs.coder_cache import coder_cache_clear
    from repro.core.codec import get_codec
    from repro.core.message import serial

    baseline = {}
    baseline_path = RESULTS_DIR / "BENCH_codecs_baseline.json"
    if baseline_path.exists():
        baseline = json.loads(baseline_path.read_text()).get("rows", {})

    results = {}
    rows = []
    for flavor, gen in [
        ("text", synth_text),
        ("log", synth_log),
        ("graph", synth_edges),
    ]:
        for mib in sizes_mib:
            data = gen(int(mib * MIB))
            s = serial(data)
            for codec in ("lz77", "huffman", "fse"):
                spec = get_codec(codec)
                reps = 3 if mib <= 1 else 1
                te, td = [], []
                for _ in range(reps):
                    coder_cache_clear()
                    t0 = time.perf_counter()
                    outs, header = spec.run_encode([s], {})
                    te.append(time.perf_counter() - t0)
                    coder_cache_clear()  # decode rows measure cold-start
                    t0 = time.perf_counter()
                    back = spec.run_decode(outs, header)
                    td.append(time.perf_counter() - t0)
                assert back[0].content_bytes() == data, f"{codec} roundtrip"
                # one instrumented rep attributes time to codec stages
                coder_cache_clear()
                with _stages.collect() as enc_stages:
                    outs, header = spec.run_encode([s], {})
                coder_cache_clear()
                with _stages.collect() as dec_stages:
                    spec.run_decode(outs, header)
                key = f"{codec}/{flavor}/{mib}MiB"
                entry = {
                    "encode_mib_s": round(mib / min(te), 3),
                    "decode_mib_s": round(mib / min(td), 3),
                    "encode_stages": {
                        k: round(v, 4) for k, v in sorted(enc_stages.items())
                    },
                    "decode_stages": {
                        k: round(v, 4) for k, v in sorted(dec_stages.items())
                    },
                }
                base = baseline.get(key)
                if base:
                    entry["encode_speedup"] = round(
                        entry["encode_mib_s"] / base["encode_mib_s"], 2
                    )
                    entry["decode_speedup"] = round(
                        entry["decode_mib_s"] / base["decode_mib_s"], 2
                    )
                results[key] = entry
                derived = ";".join(
                    f"{k}={v}"
                    for k, v in entry.items()
                    if not isinstance(v, dict)
                )
                stages_flat = "|".join(
                    f"{which}.{k}={v:.4f}"
                    for which, st in (("enc", enc_stages), ("dec", dec_stages))
                    for k, v in sorted(st.items())
                )
                rows.append(
                    f"codecs/{key},{min(te)*1e6:.1f},{derived};{stages_flat}"
                )

    # ---- profile shoot-out on the graph corpus: graph: vs generic profiles.
    # End-to-end plans (selectors included), resolve cache bypassed so each
    # profile's choices are made on *this* data.  The structure-aware graph:
    # profile must beat the generic text/numeric profiles on ratio — that is
    # the acceptance floor for shipping an edge-list frontend at all.
    from repro.codecs.profiles import resolve_profile_spec

    for mib in [m for m in sizes_mib if m <= 4] or [min(sizes_mib)]:
        data = synth_edges(int(mib * MIB))
        s = serial(data)
        ratios = {}
        for prof in ("graph", "text", "numeric", "generic"):
            plan = resolve_profile_spec(prof)
            reps = 3 if mib <= 1 else 1
            te, td = [], []
            frame = b""
            for _ in range(reps):
                coder_cache_clear()
                t0 = time.perf_counter()
                frame = compress(plan, [s], use_resolve_cache=False)
                te.append(time.perf_counter() - t0)
                coder_cache_clear()
                t0 = time.perf_counter()
                back = decompress(frame)
                td.append(time.perf_counter() - t0)
            assert back[0].content_bytes() == data, f"profile {prof} roundtrip"
            ratios[prof] = len(data) / len(frame)
            key = f"profile_{prof}/graph/{mib}MiB"
            entry = {
                "ratio": round(ratios[prof], 3),
                "encode_mib_s": round(mib / min(te), 3),
                "decode_mib_s": round(mib / min(td), 3),
            }
            results[key] = entry
            derived = ";".join(f"{k}={v}" for k, v in entry.items())
            rows.append(f"codecs/{key},{min(te)*1e6:.1f},{derived}")
        assert ratios["graph"] > ratios["text"] and ratios["graph"] > ratios["numeric"], (
            f"graph profile must beat generic text/numeric on the edge-list"
            f" corpus, got {ratios}"
        )

    if emit_json:
        payload = {
            "schema": "BENCH_codecs/v3",  # v3: graph corpus + profile rows
            "host_cpus": os.cpu_count(),
            "usable_cpus": len(os.sched_getaffinity(0)),
            "sizes_mib": list(sizes_mib),
            "baseline": str(baseline_path.name) if baseline else None,
            "rows": results,
        }
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_codecs.json").write_text(json.dumps(payload, indent=2))
    if print_rows:
        for r in rows:
            print(r)
    return rows, results


# ------------------------------------------------------ streaming sessions
STREAM_MIB = int(os.environ.get("REPRO_STREAM_BENCH_MIB", "64"))
STREAM_CHUNK_MIB = 4
STREAM_WINDOW = 4


def _stream_worker(mode: str, src: str, dst: str, chunk_mib: int, window: int):
    """Subprocess body for one --stream measurement; prints one JSON line.

    Each mode does a warm-up rep, then times a second rep — the streaming
    rows thus measure a *warm session* (persistent pool, cached resolve,
    built tables), the one-shot rows a warm process but per-call setup.
    """
    from repro.codecs import text_profile
    from repro.core import CompressorSession, DecompressorSession, stream_io

    chunk_bytes = chunk_mib * MIB
    plan = text_profile()
    result = {"mode": mode, "bytes_in": 0, "bytes_out": 0, "seconds": 0.0}
    if mode == "noop":
        pass
    elif mode == "enc-oneshot":
        from repro.core import compress, serial

        data = Path(src).read_bytes()
        times = []
        for rep in range(3):
            t0 = time.perf_counter()
            frame = compress(plan, serial(data), chunk_bytes=chunk_bytes)
            times.append(time.perf_counter() - t0)
        result["seconds"] = min(times[1:])
        Path(dst).write_bytes(frame)
        result["bytes_in"], result["bytes_out"] = len(data), len(frame)
    elif mode == "enc-stream":
        with CompressorSession(plan, chunk_bytes=chunk_bytes, window=window) as sess:
            times = []
            for rep in range(3):
                t0 = time.perf_counter()
                stats = stream_io.compress_file(
                    src, dst, plan, chunk_bytes=chunk_bytes, session=sess
                )
                times.append(time.perf_counter() - t0)
            result["seconds"] = min(times[1:])
        result["bytes_in"], result["bytes_out"] = stats["bytes_in"], stats["bytes_out"]
        result["max_inflight"] = sess.stats["max_inflight"]
    elif mode == "dec-oneshot":
        from repro.core import decompress

        frame = Path(src).read_bytes()
        times = []
        for rep in range(3):
            t0 = time.perf_counter()
            (out,) = decompress(frame)
            times.append(time.perf_counter() - t0)
        result["seconds"] = min(times[1:])
        payload = out.content_bytes()
        Path(dst).write_bytes(payload)
        result["bytes_in"], result["bytes_out"] = len(frame), len(payload)
    elif mode == "dec-stream":
        with DecompressorSession(window=window) as sess:
            times = []
            for rep in range(3):
                t0 = time.perf_counter()
                stats = stream_io.decompress_file(src, dst, session=sess)
                times.append(time.perf_counter() - t0)
            result["seconds"] = min(times[1:])
        result["bytes_in"], result["bytes_out"] = stats["bytes_in"], stats["bytes_out"]
        result["max_inflight"] = sess.stats["max_inflight"]
    else:
        raise SystemExit(f"unknown stream worker mode {mode!r}")
    print(json.dumps(result))


def _spawn_measured(mode: str, src: str, dst: str) -> dict:
    """Run one worker in a subprocess -> its JSON result + peak RSS (MiB)."""
    cmd = [
        sys.executable, "-m", "benchmarks.engine_bench",
        "--stream-worker", mode, "--stream-src", src, "--stream-dst", dst,
        "--stream-chunk-mib", str(STREAM_CHUNK_MIB),
        "--stream-window", str(STREAM_WINDOW),
    ]
    p = subprocess.Popen(cmd, stdout=subprocess.PIPE, cwd=RESULTS_DIR.parent)
    out = p.stdout.read()
    _pid, status, ru = os.wait4(p.pid, 0)
    p.returncode = os.waitstatus_to_exitcode(status)
    if p.returncode != 0:
        raise RuntimeError(f"stream worker {mode} failed ({p.returncode})")
    result = json.loads(out.decode().strip().splitlines()[-1])
    # ru_maxrss is KiB on Linux, bytes on macOS
    scale = 1024 if sys.platform != "darwin" else 1
    result["peak_rss_mib"] = round(ru.ru_maxrss * scale / MIB, 1)
    return result


def run_stream(emit_json: bool = False, print_rows: bool = True):
    """Streaming vs one-shot: MiB/s and peak RSS, one subprocess per row."""
    rows = []
    with tempfile.TemporaryDirectory(prefix="ozl_stream_bench_") as tmp:
        src = os.path.join(tmp, "corpus.log")
        with open(src, "wb") as f:  # write in 8 MiB pieces: parent stays small
            remaining = STREAM_MIB
            seed = 0
            while remaining > 0:
                piece = min(remaining, 8)
                f.write(synth_log(piece * MIB, seed=seed))
                remaining -= piece
                seed += 1
        baseline = _spawn_measured("noop", src, os.path.join(tmp, "x"))
        results = {"baseline_rss_mib": baseline["peak_rss_mib"]}
        frame_path = os.path.join(tmp, "corpus.ozl")
        for mode, s, d in [
            ("enc-oneshot", src, os.path.join(tmp, "oneshot.ozl")),
            ("enc-stream", src, frame_path),
            ("dec-oneshot", frame_path, os.path.join(tmp, "dec1.bin")),
            ("dec-stream", frame_path, os.path.join(tmp, "dec2.bin")),
        ]:
            r = _spawn_measured(mode, s, d)
            raw = max(r["bytes_in"], r["bytes_out"])  # raw side of the copy
            entry = {
                "mib_s": round(raw / MIB / max(r["seconds"], 1e-9), 2),
                "seconds": round(r["seconds"], 4),
                "peak_rss_mib": r["peak_rss_mib"],
                "rss_delta_mib": round(
                    r["peak_rss_mib"] - baseline["peak_rss_mib"], 1
                ),
            }
            if "max_inflight" in r:
                entry["max_inflight"] = r["max_inflight"]
            results[mode] = entry
            rows.append(
                f"stream/{mode},{r['seconds']*1e6:.1f},"
                + ";".join(f"{k}={v}" for k, v in entry.items())
            )
        # sanity: streaming output must decode to the original corpus
        if Path(os.path.join(tmp, "dec2.bin")).read_bytes() != Path(src).read_bytes():
            raise AssertionError("streaming roundtrip mismatch")
        for side in ("enc", "dec"):
            one, strm = results[f"{side}-oneshot"], results[f"{side}-stream"]
            results[f"{side}_speedup"] = round(strm["mib_s"] / one["mib_s"], 2)
            results[f"{side}_rss_ratio"] = round(
                strm["rss_delta_mib"] / max(one["rss_delta_mib"], 0.1), 3
            )
    if emit_json:
        payload = {
            "schema": "BENCH_stream/v1",
            "host_cpus": os.cpu_count(),
            "usable_cpus": len(os.sched_getaffinity(0)),
            "corpus_mib": STREAM_MIB,
            "chunk_mib": STREAM_CHUNK_MIB,
            "window": STREAM_WINDOW,
            "profile": "text",
            "rows": results,
        }
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_stream.json").write_text(json.dumps(payload, indent=2))
    if print_rows:
        for r in rows:
            print(r)
    return rows, results


# ----------------------------------------------------- compression service
SERVE_KIB = int(os.environ.get("REPRO_SERVE_BENCH_KIB", "256"))
SERVE_REQS = int(os.environ.get("REPRO_SERVE_BENCH_REQS", "8"))
SERVE_CLI_REPS = int(os.environ.get("REPRO_SERVE_BENCH_CLI_REPS", "3"))
SERVE_CHUNK_KIB = 64


def _percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(q / 100 * (len(xs) - 1))))]


def run_serve(emit_json: bool = False, print_rows: bool = True):
    """Hot daemon sessions vs per-invocation CLI: req/s and latency tails.

    The daemon amortizes process startup, plan resolution, and pool
    construction across requests — the per-invocation CLI pays all three per
    call.  1/4/8 concurrent clients issue ``SERVE_REQS`` compress requests
    each over persistent connections; every returned frame is checked
    byte-identical to the offline path.
    """
    import tempfile
    import threading

    from repro.core import compress, serial
    from repro.codecs import text_profile
    from repro.service import CompressionServer, PlanRegistry, ServiceClient

    corpus = synth_log(SERVE_KIB << 10)
    chunk = SERVE_CHUNK_KIB << 10
    want = compress(text_profile(), serial(corpus), chunk_bytes=chunk)
    rows = []
    results = {
        "corpus_kib": SERVE_KIB,
        "chunk_kib": SERVE_CHUNK_KIB,
        "requests_per_client": SERVE_REQS,
        "profile": "text",
    }

    with tempfile.TemporaryDirectory(prefix="ozl_serve_bench_") as tmp:
        # -- baseline: one CLI subprocess per request (cold everything) ------
        src = os.path.join(tmp, "corpus.log")
        with open(src, "wb") as f:
            f.write(corpus)
        env = dict(os.environ)
        env["PYTHONPATH"] = str(RESULTS_DIR.parent / "src") + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
        )
        cli_times = []
        for rep in range(SERVE_CLI_REPS):
            dst = os.path.join(tmp, f"cli{rep}.ozl")
            t0 = time.perf_counter()
            subprocess.run(
                [
                    sys.executable, "-m", "repro", "compress", src, "-o", dst,
                    "--profile", "text", "--chunk-bytes", str(chunk),
                ],
                check=True, env=env, cwd=RESULTS_DIR.parent,
                capture_output=True,
            )
            cli_times.append(time.perf_counter() - t0)
        with open(os.path.join(tmp, "cli0.ozl"), "rb") as f:
            assert f.read() == want, "CLI frame diverged from in-memory path"
        cli_rps = 1.0 / (sum(cli_times) / len(cli_times))
        results["cli_per_invocation"] = {
            "req_s": round(cli_rps, 3),
            "p50_ms": round(_percentile(cli_times, 50) * 1e3, 1),
            "p99_ms": round(_percentile(cli_times, 99) * 1e3, 1),
            "reps": SERVE_CLI_REPS,
        }
        rows.append(
            f"serve/cli_per_invocation,{cli_times[0]*1e6:.1f},"
            f"req_s={results['cli_per_invocation']['req_s']}"
        )

        # -- the daemon: hot sessions, persistent connections ---------------
        registry = PlanRegistry()
        registry.register_profile("text")
        with CompressionServer(
            registry, socket_path=os.path.join(tmp, "bench.sock"),
            max_clients=8, sessions_per_plan=4,
        ) as srv:
            for n_clients in (1, 4, 8):
                latencies = [[] for _ in range(n_clients)]
                failures = []

                def client_body(i):
                    try:
                        with ServiceClient(srv.address, timeout=120.0) as c:
                            for _ in range(SERVE_REQS):
                                t0 = time.perf_counter()
                                frame, _info = c.compress_bytes(
                                    corpus, "text", chunk_bytes=chunk
                                )
                                latencies[i].append(time.perf_counter() - t0)
                                if frame != want:
                                    raise AssertionError(
                                        "service frame diverged"
                                    )
                    except Exception as err:  # surfaced after join
                        failures.append(err)

                # warm-up request so c1 doesn't pay first-touch resolution
                with ServiceClient(srv.address) as c:
                    c.compress_bytes(corpus, "text", chunk_bytes=chunk)
                threads = [
                    threading.Thread(target=client_body, args=(i,))
                    for i in range(n_clients)
                ]
                t0 = time.perf_counter()
                for t in threads:
                    t.start()
                for t in threads:
                    t.join()
                wall = time.perf_counter() - t0
                if failures:
                    raise failures[0]
                flat = [x for lane in latencies for x in lane]
                entry = {
                    "clients": n_clients,
                    "req_s": round(len(flat) / wall, 3),
                    "p50_ms": round(_percentile(flat, 50) * 1e3, 1),
                    "p99_ms": round(_percentile(flat, 99) * 1e3, 1),
                    "mib_s": round(
                        len(flat) * len(corpus) / MIB / wall, 2
                    ),
                }
                results[f"serve_c{n_clients}"] = entry
                rows.append(
                    f"serve/serve_c{n_clients},{wall/len(flat)*1e6:.1f},"
                    + ";".join(f"{k}={v}" for k, v in entry.items())
                )
            results["frames_byte_identical"] = True
        speedup = results["serve_c1"]["req_s"] / max(cli_rps, 1e-9)
        results["hot_vs_cli_speedup"] = round(speedup, 2)
        rows.append(f"serve/speedup,0.0,hot_vs_cli={speedup:.2f}")
        if speedup <= 1.0:
            raise AssertionError(
                f"hot sessions must beat per-invocation CLI throughput"
                f" (got {speedup:.2f}x)"
            )

        # -- the plane: process-pool scaling, workers=1 vs workers=N ---------
        # the same workload through the pre-forked selector-frontend plane.
        # The ratio that matters is c8 throughput at N worker processes over
        # c8 at one process — the GIL pins the threaded server near 1.0, the
        # process pool should track core count.  On a single-core host the
        # ratio is pure scheduling noise, so the scaling floor only asserts
        # when real cores are available (usable_cpus, i.e. the affinity mask
        # — os.cpu_count() lies inside containers).
        from repro.service import ServicePlane

        usable_cpus = len(os.sched_getaffinity(0))
        plane_workers = max(2, min(usable_cpus, 4))
        results["usable_cpus"] = usable_cpus
        results["plane_workers"] = plane_workers
        for n_workers in (1, plane_workers):
            plane_reg = PlanRegistry()
            plane_reg.register_profile("text")
            with ServicePlane(
                plane_reg,
                socket_path=os.path.join(tmp, f"plane{n_workers}.sock"),
                workers=n_workers, max_clients=16,
            ) as plane:
                # warm each worker once: accepts round-robin across the
                # pool, so n_workers sequential connections land one each
                for _ in range(n_workers):
                    with ServiceClient(plane.address, timeout=120.0) as c:
                        c.compress_bytes(corpus, "text", chunk_bytes=chunk)
                for n_clients in (1, 4, 8):
                    latencies = [[] for _ in range(n_clients)]
                    failures = []

                    def plane_body(i):
                        try:
                            with ServiceClient(
                                plane.address, timeout=120.0, retries=2
                            ) as c:
                                for _ in range(SERVE_REQS):
                                    t0 = time.perf_counter()
                                    frame, _info = c.compress_bytes(
                                        corpus, "text", chunk_bytes=chunk
                                    )
                                    latencies[i].append(
                                        time.perf_counter() - t0
                                    )
                                    if frame != want:
                                        raise AssertionError(
                                            "plane frame diverged"
                                        )
                        except Exception as err:
                            failures.append(err)

                    threads = [
                        threading.Thread(target=plane_body, args=(i,))
                        for i in range(n_clients)
                    ]
                    t0 = time.perf_counter()
                    for t in threads:
                        t.start()
                    for t in threads:
                        t.join()
                    wall = time.perf_counter() - t0
                    if failures:
                        raise failures[0]
                    flat = [x for lane in latencies for x in lane]
                    entry = {
                        "workers": n_workers,
                        "clients": n_clients,
                        "req_s": round(len(flat) / wall, 3),
                        "p50_ms": round(_percentile(flat, 50) * 1e3, 1),
                        "p99_ms": round(_percentile(flat, 99) * 1e3, 1),
                        "mib_s": round(
                            len(flat) * len(corpus) / MIB / wall, 2
                        ),
                    }
                    results[f"plane_w{n_workers}_c{n_clients}"] = entry
                    rows.append(
                        f"serve/plane_w{n_workers}_c{n_clients},"
                        f"{wall/len(flat)*1e6:.1f},"
                        + ";".join(f"{k}={v}" for k, v in entry.items())
                    )
        scale = results[f"plane_w{plane_workers}_c8"]["req_s"] / max(
            results["plane_w1_c8"]["req_s"], 1e-9
        )
        results["plane_c8_scaling"] = round(scale, 2)
        rows.append(
            f"serve/plane_scaling,0.0,"
            f"w{plane_workers}_over_w1_at_c8={scale:.2f};cpus={usable_cpus}"
        )
        if usable_cpus >= 2:
            if scale < 1.7:
                raise AssertionError(
                    f"process pool failed to scale: w{plane_workers} c8 is"
                    f" only {scale:.2f}x w1 c8 on {usable_cpus} cores"
                )
            if (
                results[f"plane_w{plane_workers}_c8"]["req_s"]
                < results[f"plane_w{plane_workers}_c1"]["req_s"]
            ):
                raise AssertionError(
                    "concurrency regressed throughput: plane c8 < c1"
                )

        # -- degraded mode 1: overload shedding + client retries -------------
        # a deliberately starved server (one pooled session, tiny admission
        # window) under 8 clients: instead of queueing unboundedly, excess
        # requests shed with retry-after and the clients' jittered retries
        # land them all eventually — every frame still byte-identical, and
        # the successful-request p99 stays bounded by work + backoff, not by
        # an open-ended queue
        import random

        shed_reg = PlanRegistry()
        shed_reg.register_profile("text")
        with CompressionServer(
            shed_reg, socket_path=os.path.join(tmp, "shed.sock"),
            max_clients=8, sessions_per_plan=1, admission_timeout=0.02,
        ) as srv:
            with ServiceClient(srv.address) as c:
                c.compress_bytes(corpus, "text", chunk_bytes=chunk)
            latencies = [[] for _ in range(8)]
            failures = []

            def shed_body(i):
                try:
                    with ServiceClient(
                        srv.address, timeout=120.0, retries=400,
                        backoff_base=0.005, backoff_max=0.1,
                        rng=random.Random(1000 + i),
                    ) as c:
                        for _ in range(SERVE_REQS):
                            t0 = time.perf_counter()
                            frame, _info = c.compress_bytes(
                                corpus, "text", chunk_bytes=chunk
                            )
                            latencies[i].append(time.perf_counter() - t0)
                            if frame != want:
                                raise AssertionError(
                                    "shed-mode frame diverged"
                                )
                except Exception as err:
                    failures.append(err)

            threads = [
                threading.Thread(target=shed_body, args=(i,)) for i in range(8)
            ]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            wall = time.perf_counter() - t0
            if failures:
                raise failures[0]
            sheds = srv.stats()["shed"]
            flat = [x for lane in latencies for x in lane]
            entry = {
                "clients": 8,
                "sessions": 1,
                "admission_timeout_ms": 20,
                "req_s": round(len(flat) / wall, 3),
                "p50_ms": round(_percentile(flat, 50) * 1e3, 1),
                "p99_ms": round(_percentile(flat, 99) * 1e3, 1),
                "sheds": sheds,
                "completed": len(flat),
            }
            results["serve_shed_c8"] = entry
            rows.append(
                f"serve/shed_c8,{wall/len(flat)*1e6:.1f},"
                + ";".join(f"{k}={v}" for k, v in entry.items())
            )

        # -- degraded mode 2: device-kernel faults, transparent failover -----
        # a device-backend server with every device kernel invocation failing
        # keeps serving via host re-execution; frames stay byte-identical to
        # a host server's and the quarantine means the fault tax is paid once
        from repro.reliability import FaultPlan

        u32 = np.arange((SERVE_KIB << 10) // 4, dtype=np.uint32).tobytes()
        from repro.codecs.profiles import resolve_profile_spec
        from repro.core import serial as _serial

        host_ref = compress(
            resolve_profile_spec("struct:4,4"), _serial(u32), chunk_bytes=chunk
        )
        dev_reg = PlanRegistry()
        dev_reg.register_profile("struct:4,4")
        with CompressionServer(
            dev_reg, socket_path=os.path.join(tmp, "dev.sock"),
            max_clients=4, sessions_per_plan=2, backend="device",
        ) as srv:
            lat = []
            with FaultPlan().at("device.encode.device.*", times=10**9).arm(
                all_threads=True
            ):
                with ServiceClient(srv.address, timeout=120.0) as c:
                    for _ in range(SERVE_REQS):
                        t0 = time.perf_counter()
                        frame, _info = c.compress_bytes(
                            u32, "struct:4,4", chunk_bytes=chunk
                        )
                        lat.append(time.perf_counter() - t0)
                        if frame != host_ref:
                            raise AssertionError(
                                "failover frame diverged from host path"
                            )
            health = srv.stats()["backend_health"].get("device", {})
            entry = {
                "requests": len(lat),
                "req_s": round(len(lat) / max(sum(lat), 1e-9), 3),
                "p50_ms": round(_percentile(lat, 50) * 1e3, 1),
                "p99_ms": round(_percentile(lat, 99) * 1e3, 1),
                "failovers": health.get("failovers", 0),
                "device_quarantined": bool(health.get("quarantined")),
            }
            results["serve_device_failover"] = entry
            rows.append(
                f"serve/device_failover,{sum(lat)/len(lat)*1e6:.1f},"
                + ";".join(f"{k}={v}" for k, v in entry.items())
            )
    if emit_json:
        payload = {
            "schema": "BENCH_serve/v3",
            "host_cpus": os.cpu_count(),
            "usable_cpus": len(os.sched_getaffinity(0)),
            # the number that actually bounds scaling: the affinity mask
            # (cgroup cpusets make os.cpu_count() a lie inside containers)
            "usable_cpus": len(os.sched_getaffinity(0)),
            "rows": results,
        }
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_serve.json").write_text(json.dumps(payload, indent=2))
    if print_rows:
        for r in rows:
            print(r)
    return rows, results


# ------------------------------------------------------- parallel trainer
TRAIN_KIB = int(os.environ.get("REPRO_TRAIN_BENCH_KIB", "1024"))
TRAIN_POP = int(os.environ.get("REPRO_TRAIN_BENCH_POP", "16"))
TRAIN_GENS = int(os.environ.get("REPRO_TRAIN_BENCH_GENS", "4"))


def synth_train_numeric(nbytes: int, seed: int = 0) -> bytes:
    """A smooth, bounded u32 measurement series (era5-like): the workload
    shape where candidate evaluation is dominated by GIL-releasing backend
    codecs (lzma/zlib/bz2/numpy), i.e. where the trainer's thread pool can
    actually scale."""
    rng = np.random.default_rng(seed)
    n = nbytes // 4
    walk = np.cumsum(rng.integers(-40, 44, n, dtype=np.int64))
    return (np.abs(walk) % (1 << 22)).astype(np.uint32).tobytes()


def run_train(emit_json: bool = False, print_rows: bool = True):
    """Train at workers=1 vs workers=4: byte-identity + wall-clock speedup."""
    from repro.core.message import serial
    from repro.core.serialize import serialize_plan
    from repro.training import NumericFrontend, train

    corpus = synth_train_numeric(TRAIN_KIB << 10)
    rows = []
    results = {
        "corpus_bytes": len(corpus),
        "pop_size": TRAIN_POP,
        "generations": TRAIN_GENS,
        "seed": 0,
    }
    plans_by_workers = {}
    for workers in (1, 2, 4):
        resolve_cache_clear()  # no cross-run warm-up: every run starts cold
        t0 = time.perf_counter()
        tc = train(
            [[serial(corpus)]],
            NumericFrontend(width=4),
            pop_size=TRAIN_POP,
            generations=TRAIN_GENS,
            seed=0,
            workers=workers,
        )
        dt = time.perf_counter() - t0
        plans_by_workers[workers] = tuple(
            serialize_plan(p) for p, _, _ in tc.pareto_plans()
        )
        results[f"workers_{workers}"] = {
            "seconds": round(dt, 3),
            "evaluations": int(tc.stats["evaluations"]),
            "pruned_static": int(tc.stats["pruned_static"]),
            "eval_wall_seconds": round(tc.stats["eval_wall_seconds"], 3),
            "pareto_points": len(tc.points),
        }
        rows.append(
            f"train/workers_{workers},{dt*1e6:.1f},"
            f"evals={int(tc.stats['evaluations'])};points={len(tc.points)}"
        )
    if any(p != plans_by_workers[1] for p in plans_by_workers.values()):
        raise AssertionError("trainer determinism violated across worker counts")
    speedup = results["workers_1"]["seconds"] / results["workers_4"]["seconds"]
    results["plans_identical"] = True
    results["speedup"] = round(speedup, 2)
    rows.append(f"train/speedup,{0:.1f},speedup={speedup:.2f};identical=1")

    # static pruning: the analyzer rejects ill-typed genomes before trial
    # compression.  Same seed must emit a byte-identical Pareto front with
    # strictly fewer candidate encodes (CSV mixes string/numeric clusters, so
    # the search actually produces ill-typed genomes to prune).
    from repro.training import CsvFrontend

    csv_rows = b"".join(
        b"%d,%d,%d\n" % (i, (i * 31) % 997, 50_000 - i)
        for i in range(max(TRAIN_KIB, 64) * 4)
    )
    prune_plans = {}
    for prune in (True, False):
        resolve_cache_clear()
        t0 = time.perf_counter()
        tc = train(
            [[serial(csv_rows)]],
            CsvFrontend(n_cols=3),
            pop_size=TRAIN_POP,
            generations=TRAIN_GENS,
            seed=0,
            workers=2,
            static_prune=prune,
        )
        dt = time.perf_counter() - t0
        prune_plans[prune] = tuple(
            sorted(serialize_plan(p) for p, _, _ in tc.pareto_plans())
        )
        key = "prune_on" if prune else "prune_off"
        evals = int(tc.stats["evaluations"])
        pruned = int(tc.stats["pruned_static"])
        results[key] = {
            "seconds": round(dt, 3),
            "evaluations": evals,
            "pruned_static": pruned,
            "trial_compressions": evals - pruned,
            "eval_wall_seconds": round(tc.stats["eval_wall_seconds"], 3),
        }
        rows.append(
            f"train/{key},{dt*1e6:.1f},"
            f"evals={evals};pruned_static={pruned};trials={evals - pruned}"
        )
    if prune_plans[True] != prune_plans[False]:
        raise AssertionError(
            "static pruning changed the Pareto front (analyzer unsound)"
        )
    saved = (
        results["prune_off"]["trial_compressions"]
        - results["prune_on"]["trial_compressions"]
    )
    results["prune_identical"] = True
    results["prune_trials_saved"] = saved
    rows.append(f"train/prune_saved,{0:.1f},trials_saved={saved};identical=1")
    if emit_json:
        payload = {
            "schema": "BENCH_train/v1",
            "host_cpus": os.cpu_count(),
            "usable_cpus": len(os.sched_getaffinity(0)),
            "rows": results,
        }
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_train.json").write_text(json.dumps(payload, indent=2))
    if print_rows:
        for r in rows:
            print(r)
    return rows, results


def _big_input():
    rng = np.random.default_rng(0)
    n = TOTAL_BYTES // 4
    return numeric(np.cumsum(rng.integers(0, 50, n, dtype=np.int64)).astype(np.uint32))


def _time_compress(plan, stream, **kw):
    t0 = time.perf_counter()
    frame = compress(plan, stream, **kw)
    return time.perf_counter() - t0, frame


def run(print_rows: bool = True):
    rows = []

    # -- resolve cache: selector expansion amortized across calls ------------
    from repro.codecs import generic_profile

    resolve_cache_clear()
    prof = generic_profile()
    small = numeric(np.cumsum(np.random.default_rng(1).integers(0, 9, 1 << 16)).astype(np.uint32))
    n_calls = 6
    t0 = time.perf_counter()
    for _ in range(n_calls):
        compress(prof, small)
    per_call_us = (time.perf_counter() - t0) / n_calls * 1e6
    info = resolve_cache_info()
    top_level_hits = n_calls - 1  # first call misses, the rest reuse
    hit_rate = info["hits"] / max(info["hits"] + info["misses"], 1)
    rows.append(
        f"engine/resolve_cache,{per_call_us:.1f},"
        f"hit_rate={hit_rate:.2f};hits={info['hits']};misses={info['misses']};"
        f"calls={n_calls};top_level_hits={top_level_hits}"
    )

    # -- backend + chunked throughput on the big input -----------------------
    stream = _big_input()
    raw_mib = stream.nbytes / MIB
    plan = pipeline("delta", "transpose", ("zlib_backend", {"level": 1}))

    t_host, frame_host = _time_compress(plan, stream)
    assert decompress(frame_host)[0].content_bytes() == stream.content_bytes()
    rows.append(
        f"engine/host_single,{t_host*1e6:.1f},"
        f"c_mibs={raw_mib/t_host:.2f};size={len(frame_host)};input_mib={raw_mib:.0f}"
    )

    # warm the jit caches so device_single measures steady state
    warm = numeric(stream.data[: 1 << 16])
    _time_compress(pipeline("delta", "transpose"), warm, backend="device")
    t_dev, frame_dev = _time_compress(plan, stream, backend="device")
    assert frame_dev == frame_host, "device frame must be byte-identical"
    rows.append(
        f"engine/device_single,{t_dev*1e6:.1f},"
        f"c_mibs={raw_mib/t_dev:.2f};size={len(frame_dev)};bit_exact=1"
    )

    t_chunk, frame_chunk = _time_compress(plan, stream, chunk_bytes=CHUNK_BYTES)
    assert decompress(frame_chunk)[0].content_bytes() == stream.content_bytes()
    speedup = t_host / t_chunk
    rows.append(
        f"engine/chunked_host,{t_chunk*1e6:.1f},"
        f"c_mibs={raw_mib/t_chunk:.2f};size={len(frame_chunk)};"
        f"chunk_mib={CHUNK_BYTES/MIB:.0f};speedup={speedup:.2f};"
        f"workers={os.cpu_count()}"
    )

    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--codecs", action="store_true", help="run the codec section")
    ap.add_argument(
        "--codecs-only", action="store_true", help="skip the engine section"
    )
    ap.add_argument(
        "--json", action="store_true", help="write results/BENCH_codecs.json"
    )
    ap.add_argument(
        "--sizes",
        default="1,16,64",
        help="comma-separated codec benchmark sizes in MiB (floats ok)",
    )
    ap.add_argument(
        "--stream", action="store_true",
        help="run the streaming-session section (results/BENCH_stream.json"
        " with --json)",
    )
    ap.add_argument(
        "--stream-only", action="store_true", help="skip the engine section"
    )
    ap.add_argument(
        "--train", action="store_true",
        help="run the parallel-trainer section (results/BENCH_train.json"
        " with --json)",
    )
    ap.add_argument(
        "--train-only", action="store_true", help="skip the engine section"
    )
    ap.add_argument(
        "--serve", action="store_true",
        help="run the compression-service section (results/BENCH_serve.json"
        " with --json)",
    )
    ap.add_argument(
        "--serve-only", action="store_true", help="skip the engine section"
    )
    ap.add_argument("--stream-worker", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--stream-src", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--stream-dst", default=None, help=argparse.SUPPRESS)
    ap.add_argument("--stream-chunk-mib", type=int, default=STREAM_CHUNK_MIB,
                    help=argparse.SUPPRESS)
    ap.add_argument("--stream-window", type=int, default=STREAM_WINDOW,
                    help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.stream_worker:
        _stream_worker(
            args.stream_worker, args.stream_src, args.stream_dst,
            args.stream_chunk_mib, args.stream_window,
        )
        raise SystemExit(0)
    print("name,us_per_call,derived")
    if not (args.codecs_only or args.stream_only or args.train_only or args.serve_only):
        run()
    if args.codecs or args.codecs_only or (
        args.json
        and not (args.stream or args.stream_only or args.train or args.train_only
                 or args.serve or args.serve_only)
    ):
        sizes = tuple(
            int(x) if float(x) == int(float(x)) else float(x)
            for x in args.sizes.split(",")
        )
        run_codecs(sizes_mib=sizes, emit_json=args.json)
    if args.stream or args.stream_only:
        run_stream(emit_json=args.json)
    if args.train or args.train_only:
        run_train(emit_json=args.json)
    if args.serve or args.serve_only:
        run_serve(emit_json=args.json)
