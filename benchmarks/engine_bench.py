"""Engine-phase benchmarks: resolve-cache hit rate, host vs device backend,
chunked-parallel throughput — and the codec hot-path section.

Rows (CSV, appended to benchmarks/run.py output):
    engine/resolve_cache      — selector profile compressed repeatedly;
                                derived shows the cache hit rate
    engine/host_single        — one-shot host compression of the big input
    engine/device_single      — same plan via the device backend
    engine/chunked_host       — chunk_bytes split, thread-pool execution;
                                derived shows the speedup vs host_single
                                (acceptance floor: >= 1.5x on >= 32 MiB)

``--codecs`` additionally benchmarks the lz77/huffman/fse hot paths on two
canonical corpora — "text" (zipfian prose, 2^17-word vocabulary, exponent
1.05: natural-language-like statistics) and "log" (structured log lines,
OpenZL's home turf) — at 1 MiB and 16 MiB, encode and decode.  ``--json``
writes the results to ``results/BENCH_codecs.json``; when
``results/BENCH_codecs_baseline.json`` (the pre-vectorization measurements,
same generators, same host) is present, per-row speedups are recorded so the
perf trajectory of the serial-hot-path work stays on the record.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core import (
    CompressionCtx,
    compress,
    decompress,
    numeric,
    pipeline,
    resolve_cache_clear,
    resolve_cache_info,
)

MIB = 1 << 20
TOTAL_BYTES = int(os.environ.get("REPRO_ENGINE_BENCH_MIB", "32")) * MIB
CHUNK_BYTES = 4 * MIB
RESULTS_DIR = Path(__file__).resolve().parents[1] / "results"


# ------------------------------------------------------ canonical corpora
def synth_text(nbytes: int, seed: int = 0) -> bytes:
    """Zipfian prose: 2^17-word vocabulary, exponent 1.05 (Zipf's law for
    natural language), word lengths 2-11.  Fully vectorized assembly."""
    vocab_size = 1 << 17
    rng = np.random.default_rng(seed)
    lens = rng.integers(2, 12, vocab_size).astype(np.int64)
    letters = rng.integers(97, 123, int(lens.sum())).astype(np.uint8)
    bounds = np.concatenate([[0], np.cumsum(lens)])
    w = 1.0 / np.arange(1, vocab_size + 1) ** 1.05
    w /= w.sum()
    idx = rng.choice(vocab_size, size=nbytes // 4 + 16, p=w)
    wl = lens[idx]
    ends = np.cumsum(wl + 1)
    starts = ends - 1 - wl
    out = np.full(int(ends[-1]), 32, np.uint8)
    intra = np.arange(int(wl.sum()), dtype=np.int64) - np.repeat(
        np.cumsum(wl) - wl, wl
    )
    out[np.repeat(starts, wl) + intra] = letters[np.repeat(bounds[idx], wl) + intra]
    return out[:nbytes].tobytes().ljust(nbytes, b" ")


def synth_log(nbytes: int, seed: int = 0) -> bytes:
    """Structured log lines: timestamps, hex ids, k=v fields — the
    structured-data shape the paper's graph model targets."""
    rng = np.random.default_rng(seed)
    levels = [b"INFO", b"WARN", b"DEBUG", b"ERROR"]
    services = [b"auth", b"billing", b"ingest", b"frontend", b"search", b"cache"]
    verbs = [b"handled", b"rejected", b"queued", b"retried", b"flushed"]
    lines = []
    total = 0
    t = 1753862400.0
    while total < nbytes + 256:
        t += float(rng.exponential(0.05))
        line = (
            b"2026-07-30T%02d:%02d:%06.3fZ %s %s req=%016x user=%08d %s in"
            b" %dus path=/api/v2/%s/%d\n"
            % (
                int(t // 3600) % 24,
                int(t // 60) % 60,
                t % 60,
                levels[int(rng.choice(4, p=[0.7, 0.15, 0.1, 0.05]))],
                services[int(rng.integers(6))],
                int(rng.integers(0, 1 << 63)),
                int(rng.integers(0, 10**8)),
                verbs[int(rng.integers(5))],
                int(rng.integers(10, 99999)),
                services[int(rng.integers(6))],
                int(rng.integers(0, 9999)),
            )
        )
        lines.append(line)
        total += len(line)
    return b"".join(lines)[:nbytes]


def run_codecs(sizes_mib=(1, 16), emit_json=False, print_rows=True):
    """Benchmark the lz77/huffman/fse hot paths; optionally write JSON."""
    from repro.codecs.coder_cache import coder_cache_clear
    from repro.core.codec import get_codec
    from repro.core.message import serial

    baseline = {}
    baseline_path = RESULTS_DIR / "BENCH_codecs_baseline.json"
    if baseline_path.exists():
        baseline = json.loads(baseline_path.read_text()).get("rows", {})

    results = {}
    rows = []
    for flavor, gen in [("text", synth_text), ("log", synth_log)]:
        for mib in sizes_mib:
            data = gen(int(mib * MIB))
            s = serial(data)
            for codec in ("lz77", "huffman", "fse"):
                spec = get_codec(codec)
                reps = 3 if mib <= 1 else 1
                te, td = [], []
                for _ in range(reps):
                    coder_cache_clear()
                    t0 = time.perf_counter()
                    outs, header = spec.run_encode([s], {})
                    te.append(time.perf_counter() - t0)
                    coder_cache_clear()  # decode rows measure cold-start
                    t0 = time.perf_counter()
                    back = spec.run_decode(outs, header)
                    td.append(time.perf_counter() - t0)
                assert back[0].content_bytes() == data, f"{codec} roundtrip"
                key = f"{codec}/{flavor}/{mib}MiB"
                entry = {
                    "encode_mib_s": round(mib / min(te), 3),
                    "decode_mib_s": round(mib / min(td), 3),
                }
                base = baseline.get(key)
                if base:
                    entry["encode_speedup"] = round(
                        entry["encode_mib_s"] / base["encode_mib_s"], 2
                    )
                    entry["decode_speedup"] = round(
                        entry["decode_mib_s"] / base["decode_mib_s"], 2
                    )
                results[key] = entry
                derived = ";".join(f"{k}={v}" for k, v in entry.items())
                rows.append(f"codecs/{key},{min(te)*1e6:.1f},{derived}")
    if emit_json:
        payload = {
            "schema": "BENCH_codecs/v1",
            "host_cpus": os.cpu_count(),
            "sizes_mib": list(sizes_mib),
            "baseline": str(baseline_path.name) if baseline else None,
            "rows": results,
        }
        RESULTS_DIR.mkdir(parents=True, exist_ok=True)
        (RESULTS_DIR / "BENCH_codecs.json").write_text(json.dumps(payload, indent=2))
    if print_rows:
        for r in rows:
            print(r)
    return rows, results


def _big_input():
    rng = np.random.default_rng(0)
    n = TOTAL_BYTES // 4
    return numeric(np.cumsum(rng.integers(0, 50, n, dtype=np.int64)).astype(np.uint32))


def _time_compress(plan, stream, **kw):
    t0 = time.perf_counter()
    frame = compress(plan, stream, **kw)
    return time.perf_counter() - t0, frame


def run(print_rows: bool = True):
    rows = []

    # -- resolve cache: selector expansion amortized across calls ------------
    from repro.codecs import generic_profile

    resolve_cache_clear()
    prof = generic_profile()
    small = numeric(np.cumsum(np.random.default_rng(1).integers(0, 9, 1 << 16)).astype(np.uint32))
    n_calls = 6
    t0 = time.perf_counter()
    for _ in range(n_calls):
        compress(prof, small)
    per_call_us = (time.perf_counter() - t0) / n_calls * 1e6
    info = resolve_cache_info()
    top_level_hits = n_calls - 1  # first call misses, the rest reuse
    hit_rate = info["hits"] / max(info["hits"] + info["misses"], 1)
    rows.append(
        f"engine/resolve_cache,{per_call_us:.1f},"
        f"hit_rate={hit_rate:.2f};hits={info['hits']};misses={info['misses']};"
        f"calls={n_calls};top_level_hits={top_level_hits}"
    )

    # -- backend + chunked throughput on the big input -----------------------
    stream = _big_input()
    raw_mib = stream.nbytes / MIB
    plan = pipeline("delta", "transpose", ("zlib_backend", {"level": 1}))

    t_host, frame_host = _time_compress(plan, stream)
    assert decompress(frame_host)[0].content_bytes() == stream.content_bytes()
    rows.append(
        f"engine/host_single,{t_host*1e6:.1f},"
        f"c_mibs={raw_mib/t_host:.2f};size={len(frame_host)};input_mib={raw_mib:.0f}"
    )

    # warm the jit caches so device_single measures steady state
    warm = numeric(stream.data[: 1 << 16])
    _time_compress(pipeline("delta", "transpose"), warm, backend="device")
    t_dev, frame_dev = _time_compress(plan, stream, backend="device")
    assert frame_dev == frame_host, "device frame must be byte-identical"
    rows.append(
        f"engine/device_single,{t_dev*1e6:.1f},"
        f"c_mibs={raw_mib/t_dev:.2f};size={len(frame_dev)};bit_exact=1"
    )

    t_chunk, frame_chunk = _time_compress(plan, stream, chunk_bytes=CHUNK_BYTES)
    assert decompress(frame_chunk)[0].content_bytes() == stream.content_bytes()
    speedup = t_host / t_chunk
    rows.append(
        f"engine/chunked_host,{t_chunk*1e6:.1f},"
        f"c_mibs={raw_mib/t_chunk:.2f};size={len(frame_chunk)};"
        f"chunk_mib={CHUNK_BYTES/MIB:.0f};speedup={speedup:.2f};"
        f"workers={os.cpu_count()}"
    )

    if print_rows:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--codecs", action="store_true", help="run the codec section")
    ap.add_argument(
        "--codecs-only", action="store_true", help="skip the engine section"
    )
    ap.add_argument(
        "--json", action="store_true", help="write results/BENCH_codecs.json"
    )
    ap.add_argument(
        "--sizes",
        default="1,16",
        help="comma-separated codec benchmark sizes in MiB (floats ok)",
    )
    args = ap.parse_args()
    print("name,us_per_call,derived")
    if not args.codecs_only:
        run()
    if args.codecs or args.codecs_only or args.json:
        sizes = tuple(
            int(x) if float(x) == int(float(x)) else float(x)
            for x in args.sizes.split(",")
        )
        run_codecs(sizes_mib=sizes, emit_json=args.json)
