"""Paper Table III: training-set size, % of total dataset, training speed
(MiB/min) per benchmark dataset."""
from __future__ import annotations

from .trained import get_trained


def run(print_rows: bool = True):
    trained = get_trained()
    out = []
    for name, entry in trained.items():
        st = entry["stats"]
        total = sum(s.nbytes for s in entry["streams"])
        train_mib = st["train_bytes"] / (1 << 20)
        pct = 100.0 * st["train_bytes"] / total
        speed = st["train_speed_mib_min"]
        out.append((name, train_mib, pct, speed))
        if print_rows:
            print(
                f"t3_training/{name},{st['train_seconds']*1e6:.0f},"
                f"train_mib={train_mib:.2f};pct_of_total={pct:.2f};"
                f"mib_per_min={speed:.2f};clusters={int(st['n_clusters'])}"
            )
    if print_rows:
        print("# paper Table III training speeds: 1.1-11.6 MiB/min (ours should be same order)")
    return out


def main():
    run()


if __name__ == "__main__":
    main()
