"""Shared benchmark machinery: competitor registry + timing."""
from __future__ import annotations

import bz2
import lzma
import time
import zlib
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from repro.core import Compressor, Stream, compress, decompress
from repro.core.engine import CompressionCtx
from repro.core.graph import Plan


@dataclass
class Result:
    name: str
    raw_bytes: int
    compressed_bytes: int
    c_seconds: float
    d_seconds: float

    @property
    def ratio(self) -> float:
        return self.raw_bytes / max(self.compressed_bytes, 1)

    @property
    def c_mibs(self) -> float:
        return self.raw_bytes / (1 << 20) / max(self.c_seconds, 1e-9)

    @property
    def d_mibs(self) -> float:
        return self.raw_bytes / (1 << 20) / max(self.d_seconds, 1e-9)


def time_codec(
    name: str,
    data: bytes,
    enc: Callable[[bytes], bytes],
    dec: Callable[[bytes], bytes],
    *,
    check: bool = True,
) -> Result:
    t0 = time.perf_counter()
    blob = enc(data)
    t1 = time.perf_counter()
    back = dec(blob)
    t2 = time.perf_counter()
    if check and back != data:
        raise AssertionError(f"{name}: roundtrip mismatch")
    return Result(name, len(data), len(blob), t1 - t0, t2 - t1)


# competitors available offline; cmix/NNCP are not runnable in this container
# (paper Table IV lists them at ~0.001-0.003 MiB/s; noted in output headers).
COMPETITORS: Dict[str, Tuple[Callable, Callable]] = {
    "zlib-1": (lambda d: zlib.compress(d, 1), zlib.decompress),
    "zlib-6": (lambda d: zlib.compress(d, 6), zlib.decompress),
    "zlib-9": (lambda d: zlib.compress(d, 9), zlib.decompress),
    "xz-6": (lambda d: lzma.compress(d, preset=6), lzma.decompress),
    "xz-9": (lambda d: lzma.compress(d, preset=9), lzma.decompress),
    "bz2-9": (lambda d: bz2.compress(d, 9), bz2.decompress),
}


def time_openzl_plan(
    name: str, plan: Plan, streams: List[Stream], *, level: int = 5
) -> Result:
    raw = sum(s.nbytes for s in streams)
    t0 = time.perf_counter()
    frame = compress(plan, list(streams), ctx=CompressionCtx(level=level))
    t1 = time.perf_counter()
    outs = decompress(frame)
    t2 = time.perf_counter()
    for a, b in zip(streams, outs):
        if a.content_bytes() != b.content_bytes():
            raise AssertionError(f"{name}: OpenZL roundtrip mismatch")
    return Result(name, raw, len(frame), t1 - t0, t2 - t1)


def csv_row(bench: str, res: Result) -> str:
    us = res.c_seconds * 1e6
    derived = (
        f"ratio={res.ratio:.3f};c_mibs={res.c_mibs:.2f};d_mibs={res.d_mibs:.2f};"
        f"size={res.compressed_bytes}"
    )
    return f"{bench}/{res.name},{us:.1f},{derived}"
