"""Pallas kernel micro-benchmarks: us/call in interpret mode (CPU) for the
kernel and its jnp oracle, plus the fused-vs-unfused HBM-traffic model for
K1 (numbers feed EXPERIMENTS.md §Perf/K1)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, ref

N = 1 << 18


def _time(fn, *args, reps=5):
    fn(*args).block_until_ready() if hasattr(fn(*args), "block_until_ready") else None
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def run(print_rows: bool = True):
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.cumsum(rng.integers(0, 200, N)).astype(np.uint32))
    planes = jnp.asarray(rng.integers(0, 256, (N, 4)), jnp.uint8)
    rows = []
    rows.append(("delta_encode_pallas", _time(lambda a: ops.delta_encode(a), x)))
    rows.append(("delta_encode_ref", _time(lambda a: ops.delta_encode(a, use_pallas=False), x)))
    rows.append(("delta_decode_pallas", _time(lambda a: ops.delta_decode(a), x)))
    rows.append(("byteshuffle_pallas", _time(lambda a: ops.byteshuffle(a), planes)))
    rows.append(("bitpack8_pallas", _time(lambda a: ops.bitpack(a & 0xFF, 8), x)))
    rows.append(("histogram_pallas", _time(lambda a: ops.histogram(a.astype(jnp.uint8)), x)))
    rows.append(("float_split_pallas", _time(lambda a: ops.float_split(a, 8, 23)[2], x)))
    rows.append(("fused_delta_bitpack", _time(lambda a: ops.fused_delta_bitpack(a, 8), x)))

    # K1 HBM-traffic model (bytes moved per element, bits=8):
    #   unfused: delta(read 4 + write 4) + pack(read 4 + write 1) = 13 B/elt
    #   fused:   read 4 (+ 1/512 tail reread) + write 1          =  5 B/elt
    unfused = 13.0
    fused = 5.0
    rows.append(("k1_traffic_model", 0.0))
    if print_rows:
        for name, us in rows[:-1]:
            print(f"kernels/{name},{us:.1f},n={N}")
        print(
            f"kernels/k1_traffic_model,0.0,"
            f"unfused_B_per_elt={unfused};fused_B_per_elt={fused};"
            f"traffic_cut={unfused/fused:.2f}x"
        )
    return rows


def main():
    run()


if __name__ == "__main__":
    main()
