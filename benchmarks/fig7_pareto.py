"""Paper Fig. 7: ratio-vs-speed Pareto frontiers — trained OpenZL tradeoff
points vs the level systems of zlib and xz, on two representative datasets."""
from __future__ import annotations

import bz2
import lzma
import zlib

from .common import Result, csv_row, time_codec, time_openzl_plan
from .datasets import streams_to_bytes
from .trained import get_trained

DATASETS = ("binance", "era5_wind")


def run(print_rows: bool = True):
    trained = get_trained()
    out = {}
    for name in DATASETS:
        entry = trained[name]
        streams = entry["streams"]
        blob = streams_to_bytes(streams)
        rows = []
        for lvl in (1, 3, 6, 9):
            rows.append(
                time_codec(
                    f"zlib-{lvl}", blob, lambda d, l=lvl: zlib.compress(d, l), zlib.decompress
                )
            )
        for preset in (0, 3, 6, 9):
            rows.append(
                time_codec(
                    f"xz-{preset}", blob,
                    lambda d, p=preset: lzma.compress(d, preset=p), lzma.decompress,
                )
            )
        for i, (plan, _, _) in enumerate(entry["plans"]):
            try:
                rows.append(time_openzl_plan(f"openzl-p{i}", plan, streams))
            except ValueError as e:
                # train/test range mismatch: a plan picked on the training
                # prefix may refuse the full data (e.g. range_pack > 57 bits);
                # a refusal is a skipped Pareto point, not a harness crash
                print(f"# fig7_{name}/openzl-p{i} skipped: {e}")
        out[name] = rows
        if print_rows:
            for r in rows:
                print(csv_row(f"fig7_{name}", r))
            # dominance check (paper: OpenZL frontier dominates on parquet/grib)
            oz = [r for r in rows if r.name.startswith("openzl")]
            others = [r for r in rows if not r.name.startswith("openzl")]
            dominated = sum(
                1
                for o in others
                if any(z.ratio >= o.ratio and z.c_mibs >= o.c_mibs for z in oz)
            )
            print(
                f"#  {name}: {dominated}/{len(others)} traditional points are"
                " pareto-dominated by an OpenZL point"
            )
    return out


def main():
    run()


if __name__ == "__main__":
    main()
