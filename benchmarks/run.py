"""Benchmark harness entry point: one section per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run

Emits ``name,us_per_call,derived`` CSV rows.  Sections:
    t1_sao       — paper Table I  (SAO worked example, §IV)
    fig6_*       — paper Fig. 6   (best ratios vs competitors)
    t4_speeds    — paper Table IV (mean C/D speeds)
    fig7_*       — paper Fig. 7   (ratio/speed Pareto frontiers)
    t3_training  — paper Table III (trainer stats)
    kernels      — Pallas kernel micro-bench + K1 fusion traffic model
    engine       — resolve-cache hit rate, host/device/chunked throughput
    roofline     — §Roofline terms from the dry-run artifacts
"""
from __future__ import annotations

import sys
import time


def main() -> int:
    t0 = time.time()
    print("name,us_per_call,derived")
    from . import t1_sao

    t1_sao.run()
    from . import fig6_ratios

    fig6_ratios.run()
    from . import t4_speeds

    t4_speeds.run()
    from . import fig7_pareto

    fig7_pareto.run()
    from . import t3_training

    t3_training.run()
    from . import kernels_bench

    kernels_bench.run()
    from . import engine_bench

    engine_bench.run()
    try:
        from . import roofline

        roofline.main()
    except Exception as e:  # dry-run results may be absent on fresh clones
        print(f"# roofline skipped: {e}")
    print(f"# total benchmark wall time: {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
