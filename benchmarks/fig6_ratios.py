"""Paper Fig. 6: best compression ratios of competitor systems vs trained
OpenZL compressors, across the Table-II dataset families.

cmix/NNCP are not runnable offline (paper: ~0.001 MiB/s); xz -9 / bz2 -9
represent the ratio-focused end, zlib the LZ production end."""
from __future__ import annotations

from typing import Dict, List

from .common import COMPETITORS, Result, csv_row, time_codec, time_openzl_plan
from .datasets import streams_to_bytes
from .trained import get_trained


def run(print_rows: bool = True) -> Dict[str, List[Result]]:
    trained = get_trained()
    all_results: Dict[str, List[Result]] = {}
    for name, entry in trained.items():
        streams = entry["streams"]
        blob = streams_to_bytes(streams)
        rows = []
        for comp in ("zlib-6", "zlib-9", "xz-9", "bz2-9"):
            enc, dec = COMPETITORS[comp]
            rows.append(time_codec(comp, blob, enc, dec))
        # best-ratio trained point (paper Fig.6 is the ratio-focused config);
        # fall back through the Pareto set if a plan picked on the training
        # prefix refuses the full data (train/test range mismatch)
        for plan, _, _ in sorted(entry["plans"], key=lambda t: t[1]):
            try:
                rows.append(time_openzl_plan("openzl-trained", plan, streams))
                break
            except ValueError as e:
                print(f"# fig6_{name}: trained point skipped: {e}")
        all_results[name] = rows
        if print_rows:
            for r in rows:
                print(csv_row(f"fig6_{name}", r))
            oz = rows[-1]
            best = min(rows[:-1], key=lambda r: r.compressed_bytes)
            mark = "WIN" if oz.compressed_bytes < best.compressed_bytes else "loss"
            print(
                f"#  {name}: openzl {oz.ratio:.2f}x vs best-traditional"
                f" {best.name} {best.ratio:.2f}x [{mark}]"
            )
    if print_rows:
        wins = sum(
            1
            for rows in all_results.values()
            if rows[-1].compressed_bytes < min(r.compressed_bytes for r in rows[:-1])
        )
        print(f"# fig6 summary: OpenZL best-ratio on {wins}/{len(all_results)} datasets")
    return all_results


def main():
    run()


if __name__ == "__main__":
    main()
